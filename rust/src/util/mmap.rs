//! Read-only memory mapping with a heap fallback.
//!
//! [`Mapping::map`] maps a whole file `PROT_READ`/`MAP_PRIVATE` via raw
//! `extern "C"` declarations (no libc crate — the repo vendors nothing
//! it can avoid), and dereferences to `&[u8]` exactly like an owned
//! buffer. On non-unix platforms, for empty files (a zero-length mmap
//! is `EINVAL`), or whenever the syscall fails for any reason, it
//! silently falls back to [`std::fs::read`] into a heap buffer — so
//! every caller keeps working everywhere and the mapping is purely an
//! optimization.
//!
//! Safety model: the mapping is private and read-only, so concurrent
//! readers are fine (`Send + Sync`). GoFS never rewrites a packed file
//! in place — updates go through tmp+rename, which replaces the
//! directory entry while the mapped inode lives on — so a `Mapping`
//! can never observe a torn rewrite and never SIGBUSes on truncation.

use std::fs::File;
use std::io;
use std::ops::Deref;
use std::path::Path;

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// A read-only view of a file: either a live `mmap(2)` mapping or a
/// heap buffer read with [`std::fs::read`]. Derefs to `&[u8]` either
/// way, so callers never branch on which one they got.
pub enum Mapping {
    /// A live unix memory mapping; unmapped on drop.
    #[cfg(unix)]
    Mapped {
        /// Base address returned by `mmap`.
        ptr: *mut std::os::raw::c_void,
        /// Mapped length in bytes (the file length at map time).
        len: usize,
    },
    /// Heap fallback: the whole file read into memory.
    Heap(Vec<u8>),
}

// SAFETY: the mapping is PROT_READ + MAP_PRIVATE — no writer can exist
// through this handle, and GoFS never mutates packed files in place —
// so sharing the view across threads is sound.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    /// Map `path` read-only, falling back to a heap read on non-unix
    /// platforms, on empty files, or if the syscall fails.
    pub fn map(path: &Path) -> io::Result<Mapping> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let file = File::open(path)?;
            let len = file.metadata()?.len();
            if len > 0 && len <= usize::MAX as u64 {
                let len = len as usize;
                // SAFETY: fd is a freshly opened, valid descriptor; we
                // request a private read-only mapping of the whole file
                // and check for MAP_FAILED before using the result.
                let ptr = unsafe {
                    sys::mmap(
                        std::ptr::null_mut(),
                        len,
                        sys::PROT_READ,
                        sys::MAP_PRIVATE,
                        file.as_raw_fd(),
                        0,
                    )
                };
                if ptr != sys::MAP_FAILED && !ptr.is_null() {
                    // POSIX keeps the mapping alive after the fd
                    // closes; `file` dropping here is intentional.
                    return Ok(Mapping::Mapped { ptr, len });
                }
            }
            drop(file);
        }
        Ok(Mapping::Heap(std::fs::read(path)?))
    }

    /// Force the heap path (used by tests and the `mmap=false` load
    /// option to keep both code paths honest).
    pub fn read(path: &Path) -> io::Result<Mapping> {
        Ok(Mapping::Heap(std::fs::read(path)?))
    }

    /// Whether this view is a live memory mapping (false = heap copy).
    pub fn is_mapped(&self) -> bool {
        match self {
            #[cfg(unix)]
            Mapping::Mapped { .. } => true,
            Mapping::Heap(_) => false,
        }
    }
}

impl Deref for Mapping {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            Mapping::Mapped { ptr, len } => {
                // SAFETY: ptr/len describe a live PROT_READ mapping we
                // own; it stays valid until Drop.
                unsafe { std::slice::from_raw_parts(*ptr as *const u8, *len) }
            }
            Mapping::Heap(v) => v,
        }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Mapping::Mapped { ptr, len } = *self {
            // SAFETY: exactly the region mmap returned; errors on
            // unmap are unrecoverable and ignored like libstd does.
            unsafe {
                sys::munmap(ptr, len);
            }
        }
    }
}

impl std::fmt::Debug for Mapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            #[cfg(unix)]
            Mapping::Mapped { len, .. } => {
                f.debug_struct("Mapping::Mapped").field("len", len).finish()
            }
            Mapping::Heap(v) => {
                f.debug_struct("Mapping::Heap").field("len", &v.len()).finish()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join("goffish_mmap_tests")
            .join(format!("{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn mapped_bytes_equal_read_bytes() {
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let p = tmpfile("data.bin", &data);
        let m = Mapping::map(&p).unwrap();
        let r = Mapping::read(&p).unwrap();
        assert_eq!(&m[..], &data[..]);
        assert_eq!(&r[..], &data[..]);
        assert!(!r.is_mapped());
        #[cfg(unix)]
        assert!(m.is_mapped(), "unix should produce a live mapping");
    }

    #[test]
    fn empty_file_falls_back_to_heap() {
        let p = tmpfile("empty.bin", b"");
        let m = Mapping::map(&p).unwrap();
        assert!(!m.is_mapped());
        assert!(m.is_empty());
    }

    #[test]
    fn missing_file_is_an_error() {
        let p = std::env::temp_dir().join("goffish_mmap_tests_no_such_file");
        assert!(Mapping::map(&p).is_err());
        assert!(Mapping::read(&p).is_err());
    }

    #[test]
    fn mapping_survives_tmp_rename_replacement() {
        // GoFS's update discipline: never rewrite in place, always
        // tmp+rename. The old mapping must keep serving the old bytes.
        let p = tmpfile("gen.bin", b"generation-0");
        let m = Mapping::map(&p).unwrap();
        let tmp = p.with_extension("tmp");
        std::fs::write(&tmp, b"generation-1").unwrap();
        std::fs::rename(&tmp, &p).unwrap();
        assert_eq!(&m[..], b"generation-0");
        let m2 = Mapping::map(&p).unwrap();
        assert_eq!(&m2[..], b"generation-1");
    }

    #[test]
    fn mapping_is_shareable_across_threads() {
        let data: Vec<u8> = (0..4096u32).flat_map(|i| i.to_le_bytes()).collect();
        let p = tmpfile("shared.bin", &data);
        let m = std::sync::Arc::new(Mapping::map(&p).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                let want = data.clone();
                std::thread::spawn(move || assert_eq!(&m[..], &want[..]))
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
