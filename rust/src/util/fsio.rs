//! Durable filesystem primitives shared by the subsystems that commit
//! by rename (the checkpoint store and the GoFS packed-partition
//! rewrite).

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

/// Durable write-then-rename: the payload is fsynced before the rename
/// and the containing directory after it (best-effort — not every
/// platform lets a directory be opened), so a machine death right
/// after "commit" cannot leave a zero-length or partial file behind
/// the rename.
pub fn persist(tmp: &Path, dst: &Path, bytes: &[u8]) -> Result<()> {
    {
        use std::io::Write;
        let mut f =
            fs::File::create(tmp).with_context(|| format!("create {}", tmp.display()))?;
        f.write_all(bytes)
            .with_context(|| format!("write {}", tmp.display()))?;
        f.sync_all()
            .with_context(|| format!("sync {}", tmp.display()))?;
    }
    fs::rename(tmp, dst).with_context(|| format!("commit {}", dst.display()))?;
    if let Some(parent) = dst.parent() {
        if let Ok(d) = fs::File::open(parent) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn persist_replaces_destination_atomically() {
        let dir = std::env::temp_dir()
            .join(format!("goffish_fsio_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        let dst = dir.join("data.bin");
        persist(&dir.join("data.tmp"), &dst, b"first").unwrap();
        assert_eq!(fs::read(&dst).unwrap(), b"first");
        persist(&dir.join("data.tmp"), &dst, b"second").unwrap();
        assert_eq!(fs::read(&dst).unwrap(), b"second");
        // The temp file never survives a successful persist.
        assert!(!dir.join("data.tmp").exists());
        let _ = fs::remove_dir_all(&dir);
    }
}
