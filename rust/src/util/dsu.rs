//! Union-find (disjoint-set union) with path halving + union by size.
//!
//! Used for sub-graph discovery in GoFS partitions (`gofs::subgraph`) and
//! as the ground-truth component oracle in tests and `graph::props`.

/// Disjoint-set over `0..n`.
#[derive(Clone, Debug)]
pub struct Dsu {
    parent: Vec<u32>,
    size: Vec<u32>,
    components: usize,
}

impl Dsu {
    pub fn new(n: usize) -> Self {
        Self {
            parent: (0..n as u32).collect(),
            size: vec![1; n],
            components: n,
        }
    }

    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Number of disjoint components.
    pub fn components(&self) -> usize {
        self.components
    }

    /// Find with path halving.
    pub fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
        x
    }

    /// Union by size; returns true if the two were in different sets.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra as usize] < self.size[rb as usize] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb as usize] = ra;
        self.size[ra as usize] += self.size[rb as usize];
        self.components -= 1;
        true
    }

    pub fn same(&mut self, a: u32, b: u32) -> bool {
        self.find(a) == self.find(b)
    }

    /// Size of the component containing `x`.
    pub fn component_size(&mut self, x: u32) -> usize {
        let r = self.find(x);
        self.size[r as usize] as usize
    }

    /// Dense relabeling: maps each vertex to a component index in
    /// `0..components()`, in order of first appearance.
    pub fn labels(&mut self) -> Vec<u32> {
        let n = self.parent.len();
        let mut label = vec![u32::MAX; n];
        let mut next = 0u32;
        let mut out = Vec::with_capacity(n);
        for v in 0..n as u32 {
            let r = self.find(v) as usize;
            if label[r] == u32::MAX {
                label[r] = next;
                next += 1;
            }
            out.push(label[r]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn basic_union_find() {
        let mut d = Dsu::new(5);
        assert_eq!(d.components(), 5);
        assert!(d.union(0, 1));
        assert!(d.union(1, 2));
        assert!(!d.union(0, 2));
        assert_eq!(d.components(), 3);
        assert!(d.same(0, 2));
        assert!(!d.same(0, 3));
        assert_eq!(d.component_size(1), 3);
    }

    #[test]
    fn labels_are_dense_and_consistent() {
        let mut d = Dsu::new(6);
        d.union(0, 3);
        d.union(4, 5);
        let labels = d.labels();
        assert_eq!(labels.len(), 6);
        assert_eq!(labels[0], labels[3]);
        assert_eq!(labels[4], labels[5]);
        assert_ne!(labels[0], labels[4]);
        let max = *labels.iter().max().unwrap() as usize;
        assert_eq!(max + 1, d.components());
    }

    #[test]
    fn chain_union_single_component() {
        let n = 1000;
        let mut d = Dsu::new(n);
        for i in 0..n - 1 {
            d.union(i as u32, i as u32 + 1);
        }
        assert_eq!(d.components(), 1);
        assert_eq!(d.component_size(0), n);
    }

    #[test]
    fn random_unions_match_component_count_invariant() {
        let mut rng = Rng::new(99);
        let n = 200;
        let mut d = Dsu::new(n);
        let mut merges = 0;
        for _ in 0..500 {
            let a = rng.index(n) as u32;
            let b = rng.index(n) as u32;
            if d.union(a, b) {
                merges += 1;
            }
        }
        assert_eq!(d.components(), n - merges);
    }
}
