//! Compact vertex-id → local-slot lookup for the compute inner loop.
//!
//! Both engines store a sub-graph's vertices as a sorted `Vec<u32>` of
//! global ids where the *position* is the local id. Resolving a global
//! id therefore costs a binary search per message — the per-vertex
//! overhead the GoFFish paper calls out. [`VertexIndex`] replaces it:
//!
//! * **Dense** — when the id span is close to the vertex count (the
//!   common case after contiguous relabeling), a direct-indexed slot
//!   table gives O(1) lookup: `slots[id - base]`.
//! * **Sorted** — when ids are sparse (u32-gapped), the dense table
//!   would waste memory, so we keep the binary search but over a copy
//!   owned by the index, making the two variants interchangeable.
//!
//! The variant never affects results — only lookup mechanics — which
//! the engine parity tests pin by running both.

/// Slot sentinel for "no vertex at this id" in the dense table.
const ABSENT: u32 = u32::MAX;

/// Maps a global [`crate::graph::VertexId`] to its local slot (the
/// position in the sub-graph's sorted vertex list).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VertexIndex {
    /// Direct-indexed table over the id span `[base, base + slots.len())`.
    Dense {
        /// Smallest global id in the set.
        base: u32,
        /// `slots[id - base]` is the local slot, or `u32::MAX` if absent.
        slots: Vec<u32>,
    },
    /// Sorted-id fallback for sparse sets: binary search, O(log n).
    Sorted(Vec<u32>),
}

impl VertexIndex {
    /// Build the best index for `ids`, which must be sorted ascending
    /// and duplicate-free (both engines' vertex lists already are).
    /// Picks [`VertexIndex::Dense`] when the id span is at most
    /// `4 * len + 64` — past that, the slot table's memory overhead
    /// outweighs the O(1) lookup and we fall back to binary search.
    pub fn build(ids: &[u32]) -> VertexIndex {
        debug_assert!(ids.windows(2).all(|w| w[0] < w[1]), "ids must be sorted unique");
        let (min, max) = match (ids.first(), ids.last()) {
            (Some(&min), Some(&max)) => (min, max),
            _ => return VertexIndex::Dense { base: 0, slots: Vec::new() },
        };
        let span = (max - min) as usize + 1;
        if span <= ids.len().saturating_mul(4) + 64 {
            let mut slots = vec![ABSENT; span];
            for (local, &id) in ids.iter().enumerate() {
                slots[(id - min) as usize] = local as u32;
            }
            VertexIndex::Dense { base: min, slots }
        } else {
            VertexIndex::Sorted(ids.to_vec())
        }
    }

    /// Force the sorted-search fallback regardless of density — the
    /// `dense_index=false` knob, kept so parity tests can pit the two
    /// variants against each other on the same graph.
    pub fn sorted(ids: &[u32]) -> VertexIndex {
        debug_assert!(ids.windows(2).all(|w| w[0] < w[1]), "ids must be sorted unique");
        VertexIndex::Sorted(ids.to_vec())
    }

    /// Local slot of `global`, or `None` if it is not in this set.
    #[inline]
    pub fn get(&self, global: u32) -> Option<u32> {
        match self {
            VertexIndex::Dense { base, slots } => {
                let off = global.checked_sub(*base)? as usize;
                match slots.get(off) {
                    Some(&slot) if slot != ABSENT => Some(slot),
                    _ => None,
                }
            }
            VertexIndex::Sorted(ids) => {
                ids.binary_search(&global).ok().map(|i| i as u32)
            }
        }
    }

    /// Number of vertices indexed.
    pub fn len(&self) -> usize {
        match self {
            VertexIndex::Dense { slots, .. } => {
                slots.iter().filter(|&&s| s != ABSENT).count()
            }
            VertexIndex::Sorted(ids) => ids.len(),
        }
    }

    /// Whether the index is empty.
    pub fn is_empty(&self) -> bool {
        match self {
            VertexIndex::Dense { slots, .. } => slots.iter().all(|&s| s == ABSENT),
            VertexIndex::Sorted(ids) => ids.is_empty(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_ids_build_dense() {
        let ids: Vec<u32> = (100..200).collect();
        let idx = VertexIndex::build(&ids);
        assert!(matches!(idx, VertexIndex::Dense { .. }));
        for (local, &id) in ids.iter().enumerate() {
            assert_eq!(idx.get(id), Some(local as u32));
        }
        assert_eq!(idx.get(99), None);
        assert_eq!(idx.get(200), None);
        assert_eq!(idx.get(0), None);
        assert_eq!(idx.len(), 100);
    }

    #[test]
    fn gapped_ids_fall_back_to_sorted() {
        // span = 4_000_000_001 ≫ 4*3 + 64 → must not allocate a table.
        let ids = vec![7u32, 1_000_000, 4_000_000_007];
        let idx = VertexIndex::build(&ids);
        assert!(matches!(idx, VertexIndex::Sorted(_)));
        assert_eq!(idx.get(7), Some(0));
        assert_eq!(idx.get(1_000_000), Some(1));
        assert_eq!(idx.get(4_000_000_007), Some(2));
        assert_eq!(idx.get(8), None);
    }

    #[test]
    fn dense_and_sorted_agree_on_every_probe() {
        let ids = vec![3u32, 4, 9, 10, 11, 30, 31, 40];
        let dense = VertexIndex::build(&ids);
        assert!(matches!(dense, VertexIndex::Dense { .. }), "span 38 fits 4*8+64");
        let sorted = VertexIndex::sorted(&ids);
        for probe in 0..64u32 {
            assert_eq!(dense.get(probe), sorted.get(probe), "probe {probe}");
        }
    }

    #[test]
    fn empty_and_singleton_sets() {
        let empty = VertexIndex::build(&[]);
        assert!(empty.is_empty());
        assert_eq!(empty.get(0), None);
        let one = VertexIndex::build(&[42]);
        assert_eq!(one.get(42), Some(0));
        assert_eq!(one.get(41), None);
        assert_eq!(one.len(), 1);
        assert!(!one.is_empty());
    }

    #[test]
    fn boundary_ids_do_not_overflow() {
        let ids = vec![u32::MAX - 2, u32::MAX - 1];
        let idx = VertexIndex::build(&ids);
        assert_eq!(idx.get(u32::MAX - 2), Some(0));
        assert_eq!(idx.get(u32::MAX - 1), Some(1));
        assert_eq!(idx.get(u32::MAX), None);
        assert_eq!(idx.get(0), None);
    }
}
