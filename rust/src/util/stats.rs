//! Summary statistics for metrics and the Fig-5 box-whisker harness.

/// Five-number summary (+ mean/count) of a sample, as used by the paper's
/// Fig. 5 box-and-whiskers plots of per-sub-graph compute times.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
}

impl Summary {
    /// Compute from an unsorted sample; returns `None` for empty input.
    pub fn from(sample: &[f64]) -> Option<Summary> {
        if sample.is_empty() {
            return None;
        }
        let mut s: Vec<f64> = sample.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        Some(Summary {
            count: s.len(),
            min: s[0],
            q1: quantile(&s, 0.25),
            median: quantile(&s, 0.5),
            q3: quantile(&s, 0.75),
            max: s[s.len() - 1],
            mean,
        })
    }

    /// Render as the row format used by the bench harnesses.
    pub fn row(&self) -> String {
        format!(
            "n={:<6} min={:<10.6} q1={:<10.6} med={:<10.6} q3={:<10.6} max={:<10.6} mean={:.6}",
            self.count, self.min, self.q1, self.median, self.q3, self.max, self.mean
        )
    }
}

/// Linear-interpolated quantile of a *sorted* sample, `q` in `[0, 1]`.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    debug_assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median of an unsorted sample (convenience for the bench harness).
pub fn median(sample: &[f64]) -> f64 {
    let mut s = sample.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile(&s, 0.5)
}

/// Pearson correlation of two equal-length samples (used to check the
/// paper's R^2=0.9999 diameter-vs-speedup claim in bench_fig4a).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx * vy).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.q1, 2.0);
        assert_eq!(s.q3, 4.0);
        assert_eq!(s.mean, 3.0);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::from(&[]).is_none());
    }

    #[test]
    fn summary_single_element() {
        let s = Summary::from(&[7.5]).unwrap();
        assert_eq!(s.min, 7.5);
        assert_eq!(s.q1, 7.5);
        assert_eq!(s.max, 7.5);
    }

    #[test]
    fn quantile_interpolates() {
        let s = [0.0, 10.0];
        assert_eq!(quantile(&s, 0.5), 5.0);
        assert_eq!(quantile(&s, 0.25), 2.5);
    }

    #[test]
    fn median_unsorted() {
        assert_eq!(median(&[9.0, 1.0, 5.0]), 5.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }
}
