//! Core-sized worker thread pool for per-sub-graph compute dispatch.
//!
//! The paper's Gopher worker "uses a thread pool optimized for multi-core
//! CPUs to invoke the Compute on each sub-graph" (§4.2). This pool runs a
//! batch of indexed jobs and blocks until all complete (scoped fork-join —
//! exactly the superstep shape), capturing per-job wall time so the
//! metrics layer can build the Fig-5 straggler distributions.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

/// Number of jobs below which we skip thread spawn entirely.
const INLINE_THRESHOLD: usize = 2;

fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Run `jobs` indexed tasks on up to `cores` threads; returns per-job
/// elapsed seconds. `f(i)` must be safe to call concurrently for
/// distinct `i`. A panicking job is converted into an `Err` (after all
/// other jobs finish), so BSP workers can abort cleanly rather than
/// deadlock the superstep barrier.
pub fn run_indexed<F>(cores: usize, jobs: usize, f: F) -> Result<Vec<f64>>
where
    F: Fn(usize) + Sync,
{
    let mut times = vec![0.0f64; jobs];
    if jobs == 0 {
        return Ok(times);
    }
    let threads = cores.max(1).min(jobs);
    if threads == 1 || jobs < INLINE_THRESHOLD {
        for (i, t) in times.iter_mut().enumerate() {
            let t0 = Instant::now();
            if let Err(p) = std::panic::catch_unwind(AssertUnwindSafe(|| f(i))) {
                bail!("compute job {i} panicked: {}", panic_msg(p));
            }
            *t = t0.elapsed().as_secs_f64();
        }
        return Ok(times);
    }

    let next = Arc::new(AtomicUsize::new(0));
    let first_panic: Mutex<Option<(usize, String)>> = Mutex::new(None);
    // Unsafe-free sharing of the times buffer: each worker writes only the
    // slot it claimed, communicated back via a channel.
    let (tx, rx) = std::sync::mpsc::channel::<(usize, f64)>();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = Arc::clone(&next);
            let tx = tx.clone();
            let f = &f;
            let first_panic = &first_panic;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= jobs {
                    return;
                }
                let t0 = Instant::now();
                match std::panic::catch_unwind(AssertUnwindSafe(|| f(i))) {
                    Ok(()) => {
                        let _ = tx.send((i, t0.elapsed().as_secs_f64()));
                    }
                    Err(p) => {
                        let mut slot = first_panic.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some((i, panic_msg(p)));
                        }
                    }
                }
            });
        }
        drop(tx);
        for (i, dt) in rx {
            times[i] = dt;
        }
    });
    if let Some((i, msg)) = first_panic.into_inner().unwrap() {
        bail!("compute job {i} panicked: {msg}");
    }
    Ok(times)
}

/// Detected hardware parallelism (fallback 4).
pub fn num_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn all_jobs_run_exactly_once() {
        let counter = AtomicU64::new(0);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        let times = run_indexed(4, 100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
            counter.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        assert_eq!(times.len(), 100);
        assert!(times.iter().all(|&t| t >= 0.0));
    }

    #[test]
    fn zero_jobs_ok() {
        let times = run_indexed(4, 0, |_| panic!("should not run")).unwrap();
        assert!(times.is_empty());
    }

    #[test]
    fn single_core_sequential() {
        let order = std::sync::Mutex::new(Vec::new());
        run_indexed(1, 10, |i| order.lock().unwrap().push(i)).unwrap();
        assert_eq!(*order.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn more_threads_than_jobs() {
        let counter = AtomicU64::new(0);
        run_indexed(64, 3, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn times_capture_work() {
        let times = run_indexed(2, 4, |_| {
            std::thread::sleep(std::time::Duration::from_millis(5));
        })
        .unwrap();
        assert!(times.iter().all(|&t| t >= 0.004), "{times:?}");
    }

    #[test]
    fn panicking_job_becomes_error() {
        let err = run_indexed(4, 8, |i| {
            if i == 5 {
                panic!("boom {i}");
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("boom 5"), "{err}");
        // Sequential path too.
        let err = run_indexed(1, 2, |i| {
            if i == 1 {
                panic!("seq");
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("panicked"));
    }
}
