//! Deterministic PRNGs: SplitMix64 (seeding) + Xoshiro256** (streams).
//!
//! Graph generation and property tests need reproducible randomness; the
//! vendored crate set has no `rand`, so we carry the standard small-state
//! generators (Blackman & Vigna) ourselves.

/// SplitMix64: used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256**: the workhorse stream generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the reference implementation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Uniform in the inclusive integer range `[lo, hi]`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // First outputs for seed 1234567 from the reference C code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn next_below_in_bounds() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval_and_spread() {
        let mut r = Rng::new(9);
        let mut lo = 1.0f64;
        let mut hi = 0.0f64;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            lo = lo.min(x);
            hi = hi.max(x);
        }
        assert!(lo < 0.01 && hi > 0.99, "poor spread: [{lo}, {hi}]");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn chance_rates_reasonable() {
        let mut r = Rng::new(11);
        let hits = (0..10_000).filter(|_| r.chance(0.3)).count();
        assert!((2500..3500).contains(&hits), "hits={hits}");
    }
}
