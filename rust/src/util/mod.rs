//! Shared substrates: PRNG, binary codec, union-find, thread pool, stats.
//!
//! The offline vendor set has no `rand`/`serde`/`rayon`, so these are
//! implemented in-crate (see DESIGN.md §3). Everything here is dependency
//! free and unit-tested in place.

pub mod rng;
pub mod codec;
pub mod dsu;
pub mod fsio;
pub mod index;
pub mod mmap;
pub mod pool;
pub mod stats;
