//! Compact binary codec for GoFS slice files (the Kryo stand-in).
//!
//! LEB128 varints, zigzag for signed values, delta encoding for sorted id
//! runs, length-prefixed strings and f32/f64 little-endian. The framing is
//! deliberately tiny: GoFS is write-once-read-many, so there is no need
//! for schema evolution machinery — a magic + version header per file is
//! enough (see `gofs::slice`).

use anyhow::{bail, Result};

/// Append-only encoder over a byte buffer.
#[derive(Default)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap) }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// LEB128 unsigned varint (1..10 bytes).
    pub fn put_varint(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return;
            }
            self.buf.push(byte | 0x80);
        }
    }

    /// Zigzag-encoded signed varint.
    pub fn put_signed(&mut self, v: i64) {
        self.put_varint(((v << 1) ^ (v >> 63)) as u64);
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_varint(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    /// Delta-encode a *sorted* run of ids: first absolute, then gaps.
    /// Falls back to an error in debug builds if unsorted.
    pub fn put_sorted_ids(&mut self, ids: &[u64]) {
        self.put_varint(ids.len() as u64);
        let mut prev = 0u64;
        for (i, &id) in ids.iter().enumerate() {
            debug_assert!(i == 0 || id >= prev, "ids must be sorted");
            self.put_varint(if i == 0 { id } else { id - prev });
            prev = id;
        }
    }

    /// Unsorted id list (plain varints).
    pub fn put_ids(&mut self, ids: &[u64]) {
        self.put_varint(ids.len() as u64);
        for &id in ids {
            self.put_varint(id);
        }
    }
}

/// Cursor-based decoder over a byte slice.
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_at_end(&self) -> bool {
        self.pos == self.buf.len()
    }

    pub fn get_u8(&mut self) -> Result<u8> {
        if self.pos >= self.buf.len() {
            bail!("codec: unexpected end of buffer at {}", self.pos);
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }

    pub fn get_varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let byte = self.get_u8()?;
            if shift >= 64 {
                bail!("codec: varint overflow");
            }
            // The 10th byte may only carry the final bit.
            if shift == 63 && (byte & 0x7e) != 0 {
                bail!("codec: varint overflow");
            }
            v |= ((byte & 0x7f) as u64) << shift;
            if byte & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    pub fn get_signed(&mut self) -> Result<i64> {
        let v = self.get_varint()?;
        Ok(((v >> 1) as i64) ^ -((v & 1) as i64))
    }

    pub fn get_f32(&mut self) -> Result<f32> {
        let bytes = self.get_raw(4)?;
        Ok(f32::from_le_bytes(bytes.try_into().unwrap()))
    }

    pub fn get_f64(&mut self) -> Result<f64> {
        let bytes = self.get_raw(8)?;
        Ok(f64::from_le_bytes(bytes.try_into().unwrap()))
    }

    fn get_raw(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("codec: need {} bytes, have {}", n, self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn get_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.get_varint()? as usize;
        self.get_raw(n)
    }

    pub fn get_str(&mut self) -> Result<&'a str> {
        Ok(std::str::from_utf8(self.get_bytes()?)?)
    }

    pub fn get_sorted_ids(&mut self) -> Result<Vec<u64>> {
        let n = self.get_varint()? as usize;
        if n > self.remaining() {
            // Each id takes >= 1 byte; cheap corruption guard before alloc.
            bail!("codec: id run length {} exceeds buffer", n);
        }
        let mut ids = Vec::with_capacity(n);
        let mut prev = 0u64;
        for i in 0..n {
            let d = self.get_varint()?;
            prev = if i == 0 { d } else { prev.checked_add(d).ok_or_else(|| anyhow::anyhow!("codec: id overflow"))? };
            ids.push(prev);
        }
        Ok(ids)
    }

    pub fn get_ids(&mut self) -> Result<Vec<u64>> {
        let n = self.get_varint()? as usize;
        if n > self.remaining() {
            bail!("codec: id list length {} exceeds buffer", n);
        }
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            ids.push(self.get_varint()?);
        }
        Ok(ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn varint_round_trip_edges() {
        let vals = [0u64, 1, 127, 128, 16383, 16384, u32::MAX as u64, u64::MAX];
        let mut e = Encoder::new();
        for &v in &vals {
            e.put_varint(v);
        }
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        for &v in &vals {
            assert_eq!(d.get_varint().unwrap(), v);
        }
        assert!(d.is_at_end());
    }

    #[test]
    fn signed_round_trip() {
        let vals = [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN];
        let mut e = Encoder::new();
        for &v in &vals {
            e.put_signed(v);
        }
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        for &v in &vals {
            assert_eq!(d.get_signed().unwrap(), v);
        }
    }

    #[test]
    fn floats_and_strings_round_trip() {
        let mut e = Encoder::new();
        e.put_f32(3.5);
        e.put_f64(-1.25e300);
        e.put_str("goffish");
        e.put_str("");
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(d.get_f32().unwrap(), 3.5);
        assert_eq!(d.get_f64().unwrap(), -1.25e300);
        assert_eq!(d.get_str().unwrap(), "goffish");
        assert_eq!(d.get_str().unwrap(), "");
    }

    #[test]
    fn sorted_ids_delta_round_trip() {
        let ids = vec![5u64, 5, 9, 100, 100_000, u64::MAX / 2];
        let mut e = Encoder::new();
        e.put_sorted_ids(&ids);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(d.get_sorted_ids().unwrap(), ids);
    }

    #[test]
    fn truncated_buffer_errors() {
        let mut e = Encoder::new();
        e.put_str("hello world");
        let bytes = e.into_bytes();
        for cut in 0..bytes.len() {
            let mut d = Decoder::new(&bytes[..cut]);
            assert!(d.get_str().is_err(), "cut={cut} should fail");
        }
    }

    #[test]
    fn corrupted_length_detected_before_alloc() {
        let mut e = Encoder::new();
        e.put_varint(u64::MAX); // absurd element count
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert!(d.get_sorted_ids().is_err());
    }

    #[test]
    fn varint_overflow_rejected() {
        // 11 continuation bytes can't be a valid u64.
        let bytes = [0xffu8; 11];
        let mut d = Decoder::new(&bytes);
        assert!(d.get_varint().is_err());
    }

    #[test]
    fn fuzz_round_trip_mixed() {
        let mut rng = Rng::new(0xC0DEC);
        for _ in 0..200 {
            let n = rng.index(50);
            let vals: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let svals: Vec<i64> =
                (0..n).map(|_| rng.next_u64() as i64).collect();
            let mut e = Encoder::new();
            for (&u, &s) in vals.iter().zip(&svals) {
                e.put_varint(u);
                e.put_signed(s);
                e.put_f32(u as f32);
            }
            let bytes = e.into_bytes();
            let mut d = Decoder::new(&bytes);
            for (&u, &s) in vals.iter().zip(&svals) {
                assert_eq!(d.get_varint().unwrap(), u);
                assert_eq!(d.get_signed().unwrap(), s);
                assert_eq!(d.get_f32().unwrap(), u as f32);
            }
            assert!(d.is_at_end());
        }
    }
}
