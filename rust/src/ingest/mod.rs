//! Streaming bulk loader: edge list → GoFS store under bounded memory.
//!
//! The batch path (`Graph` + a partitioner + [`Store::create`]) holds
//! the whole graph in RAM, which caps ingestable size at memory. This
//! module builds the *same bytes* without ever materializing the global
//! `Graph`, in the spirit of GoFFish's `grupload` bulk loader: the edge
//! list is streamed once, partitioned online, and spilled to per-host
//! run files whenever a configurable buffer fills; a second streaming
//! pass then folds each host's runs into sub-graphs and writes its
//! partition files — one partition resident at a time.
//!
//! ```text
//!  edges.tsv ──stream──▶ pass 0: intern ids · hash-bucket endpoints
//!       │                        union same-host components (DSU)
//!       │                        buffer (u,v,w) per destination host
//!       │                        buffer full ─▶ spill run files
//!       ▼
//!  .ingest/p0_run0 p0_run1 … p1_run0 …        (arrival-ordered runs)
//!       │
//!       ▼                pass 1, host by host:
//!  concat runs in order ─▶ route each edge to its sub-graph
//!                          local CSR · remote_out · remote_in
//!                          ─▶ host<p>/ partition files  (v1/v2/v3)
//!       ▼
//!  meta.txt  ─▶  Store::open
//! ```
//!
//! ## Byte parity with the batch builder
//!
//! The acceptance bar is byte-identical stores, not merely isomorphic
//! ones, so every ordering choice mirrors the batch pipeline:
//!
//! * **Dense ids** — unweighted lists intern external ids in first-
//!   appearance order, source before target (what `GraphBuilder` does);
//!   weighted lists use the raw ids directly with `n = max + 1`
//!   (what `read_edge_list` does). Partitions hash the *dense* id.
//! * **Sub-graph numbering** — sub-graph indices are assigned per
//!   partition in order of each component's smallest vertex, and member
//!   lists ascend, exactly like `subgraph::discover`.
//! * **Edge order** — `Graph::from_edges` counting-sorts stably by
//!   source, so pushing local edges in file-arrival order reproduces
//!   the batch CSR bit-for-bit. `remote_out` is stably sorted by local
//!   vertex and `remote_in` by (local vertex, remote global id), the
//!   order `discover`'s CSR sweeps enumerate them in.
//! * **Runs concatenate in arrival order** — each record is appended
//!   to its hosts' FIFO buffers and a full buffer is flushed whole, so
//!   reading one host's runs back-to-back *is* the external merge: no
//!   heap, no sequence numbers, just a linear scan.
//!
//! ## Memory bound
//!
//! Pass 0 holds O(V) of id tables (intern map + DSU) plus the spill
//! buffer; pass 1 holds one partition's edges plus O(V) routing tables.
//! Neither pass holds the full edge list, which is what lets a spill
//! buffer smaller than the input still produce an identical store
//! (proven by `prop_streamed_store_equals_batch_store`).

use std::collections::HashMap;
use std::fs;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::gofs::store::{write_meta, write_partition_files};
use crate::gofs::{SliceFormat, Store, StoreMeta, Subgraph, SubgraphId};
use crate::gofs::subgraph::RemoteRef;
use crate::graph::csr::Graph;
use crate::partition::HashPartitioner;

/// Knobs for one streaming ingest. The defaults match the CLI's batch
/// `store` command (hash partitioner, seed 1, packed v3 output), so
/// `goffish ingest` and `goffish store` agree byte-for-byte out of the
/// box.
#[derive(Clone, Debug)]
pub struct IngestOptions {
    /// Graph name recorded in `meta.txt`.
    pub name: String,
    /// Number of hosts/partitions to scatter vertices across.
    pub hosts: u32,
    /// Slice format of the written store (packed v3 by default).
    pub format: SliceFormat,
    /// Treat edges as directed (mirrors `read_edge_list`'s flag).
    pub directed: bool,
    /// Spill threshold in **bytes** of buffered edge records; when the
    /// total across all hosts reaches it, every non-empty buffer is
    /// flushed to a run file. Values smaller than one record still
    /// admit one record at a time.
    pub spill_buffer: usize,
    /// Seed of the online [`HashPartitioner`].
    pub seed: u64,
    /// Span tracing (`ingest_pass0` / `ingest_pass1` on lane 0); a
    /// disabled tracer (the default) costs one branch per pass. The
    /// CLI enables it with `ingest --trace out.json`.
    pub trace: crate::obs::trace::Tracer,
}

impl Default for IngestOptions {
    fn default() -> Self {
        Self {
            name: "graph".to_string(),
            hosts: 2,
            format: SliceFormat::V3Packed,
            directed: false,
            spill_buffer: 64 << 20,
            seed: 1,
            trace: crate::obs::trace::Tracer::default(),
        }
    }
}

/// What one ingest did — sizes for reporting, spill accounting for
/// tests and the `ingest_throughput` bench row.
#[derive(Clone, Copy, Debug, Default)]
pub struct IngestReport {
    /// Dense vertices in the written store.
    pub vertices: u64,
    /// Edge lines ingested.
    pub edges: u64,
    /// Sub-graphs discovered across all partitions.
    pub subgraphs: u64,
    /// Times the spill threshold tripped mid-stream (the final
    /// flush-everything at end of pass 0 is not counted).
    pub spills: u64,
    /// Run files written across all hosts.
    pub runs: u64,
    /// Bytes written to run files.
    pub spilled_bytes: u64,
    /// Wall-clock seconds for the whole ingest.
    pub seconds: f64,
}

/// One spilled edge record: `u:u32 v:u32 w:f32`, little-endian.
const REC_BYTES: usize = 12;

/// Per-host FIFO spill buffers plus their on-disk run files.
struct Spiller {
    dir: PathBuf,
    bufs: Vec<Vec<(u32, u32, f32)>>,
    /// Flush when this many records are buffered in total.
    cap_records: usize,
    buffered: usize,
    runs: Vec<Vec<PathBuf>>,
    spills: u64,
    spilled_bytes: u64,
}

impl Spiller {
    fn new(dir: PathBuf, hosts: u32, spill_buffer: usize) -> Self {
        Self {
            dir,
            bufs: vec![Vec::new(); hosts as usize],
            // However tiny the budget, admit at least one record so
            // ingest degenerates to a run file per edge, not a hang.
            cap_records: (spill_buffer / REC_BYTES).max(1),
            buffered: 0,
            runs: vec![Vec::new(); hosts as usize],
            spills: 0,
            spilled_bytes: 0,
        }
    }

    fn push(&mut self, host: u32, u: u32, v: u32, w: f32) -> Result<()> {
        self.bufs[host as usize].push((u, v, w));
        self.buffered += 1;
        if self.buffered >= self.cap_records {
            self.spills += 1;
            self.flush_all()?;
        }
        Ok(())
    }

    /// Flush every non-empty buffer as one new run file per host.
    /// Flushing all hosts together keeps each host's run sequence a
    /// clean split of its arrival order — the invariant that makes
    /// pass 1's "merge" a plain concatenation.
    fn flush_all(&mut self) -> Result<()> {
        for host in 0..self.bufs.len() {
            let buf = &self.bufs[host];
            if buf.is_empty() {
                continue;
            }
            let path = self.dir.join(format!("p{host}_run{}.tmp", self.runs[host].len()));
            let file = fs::File::create(&path)
                .with_context(|| format!("create ingest run {}", path.display()))?;
            let mut out = BufWriter::new(file);
            for &(u, v, w) in buf {
                out.write_all(&u.to_le_bytes())?;
                out.write_all(&v.to_le_bytes())?;
                out.write_all(&w.to_le_bytes())?;
            }
            out.flush()
                .with_context(|| format!("flush ingest run {}", path.display()))?;
            self.spilled_bytes += (buf.len() * REC_BYTES) as u64;
            self.runs[host].push(path);
            self.bufs[host].clear();
        }
        self.buffered = 0;
        Ok(())
    }
}

/// Stream one run file's records through `f` in write order.
fn for_each_record(path: &Path, mut f: impl FnMut(u32, u32, f32)) -> Result<()> {
    let bytes =
        fs::read(path).with_context(|| format!("read ingest run {}", path.display()))?;
    ensure!(
        bytes.len() % REC_BYTES == 0,
        "torn ingest run {} ({} bytes)",
        path.display(),
        bytes.len()
    );
    for rec in bytes.chunks_exact(REC_BYTES) {
        let u = u32::from_le_bytes(rec[0..4].try_into().unwrap());
        let v = u32::from_le_bytes(rec[4..8].try_into().unwrap());
        let w = f32::from_le_bytes(rec[8..12].try_into().unwrap());
        f(u, v, w);
    }
    Ok(())
}

/// Union-find over dense vertex ids that grows as ids are interned
/// (the fixed-size `util::dsu::Dsu` needs `n` up front, which a stream
/// doesn't know). Path-halving find, union by size.
#[derive(Default)]
struct GrowDsu {
    parent: Vec<u32>,
    size: Vec<u32>,
}

impl GrowDsu {
    fn grow(&mut self, n: usize) {
        while self.parent.len() < n {
            self.parent.push(self.parent.len() as u32);
            self.size.push(1);
        }
    }

    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
        x
    }

    fn union(&mut self, a: u32, b: u32) {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return;
        }
        if self.size[ra as usize] < self.size[rb as usize] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb as usize] = ra;
        self.size[ra as usize] += self.size[rb as usize];
    }
}

/// Stream `edges` (TSV/CSV/whitespace, `src dst [weight]` per line,
/// `#` comments and blank lines skipped) into a new GoFS store at
/// `store_root`, never holding more than one partition plus the spill
/// buffer in memory. Returns the opened store and an [`IngestReport`].
///
/// Errors carry 1-based line numbers (`line 7: bad weight`), mixed
/// weighted/unweighted lines are rejected at the first conflict, and
/// the root must be empty — GoFS stores are write-once per generation,
/// and ingest always writes generation 0.
pub fn ingest_edge_list(
    edges: &Path,
    store_root: &Path,
    opts: &IngestOptions,
) -> Result<(Store, IngestReport)> {
    ensure!(opts.hosts >= 1, "ingest needs at least one host");
    if store_root.exists() {
        ensure!(
            fs::read_dir(store_root)
                .with_context(|| format!("read {}", store_root.display()))?
                .next()
                .is_none(),
            "store root {} already exists and is not empty (GoFS stores are write-once)",
            store_root.display()
        );
    }
    let t0 = Instant::now();
    let k = opts.hosts;
    let hasher = HashPartitioner::new(opts.seed);
    let tmp_dir = store_root.join(".ingest");
    fs::create_dir_all(&tmp_dir)
        .with_context(|| format!("create {}", tmp_dir.display()))?;

    let rec = opts.trace.recorder(0);

    // ---- Pass 0: stream lines; intern ids, union same-host
    // components, and spill (u, v, w) records per host.
    let span_pass0 = rec.as_ref().map(|r| r.span("ingest_pass0", "ingest"));
    let mut spiller = Spiller::new(tmp_dir.clone(), k, opts.spill_buffer);
    let mut intern: HashMap<u64, u32> = HashMap::new();
    let mut dsu = GrowDsu::default();
    let mut weighted: Option<bool> = None;
    let mut n: usize = 0;
    let mut num_edges: u64 = 0;

    let file =
        fs::File::open(edges).with_context(|| format!("open {}", edges.display()))?;
    let mut line_no = 0usize;
    for line in BufReader::new(file).lines() {
        let line = line.with_context(|| format!("read {}", edges.display()))?;
        line_no += 1;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // TSV, CSV, or plain whitespace: any run of separators splits.
        let mut toks = line
            .split(|c: char| c.is_whitespace() || c == ',')
            .filter(|t| !t.is_empty());
        let u_ext: u64 = toks
            .next()
            .unwrap_or("")
            .parse()
            .with_context(|| format!("line {line_no}: bad src"))?;
        let v_ext: u64 = toks
            .next()
            .with_context(|| format!("line {line_no}: missing dst"))?
            .parse()
            .with_context(|| format!("line {line_no}: bad dst"))?;
        // Tokens after the weight are ignored, like the batch reader.
        let w: Option<f32> = match toks.next() {
            Some(t) => Some(
                t.parse()
                    .with_context(|| format!("line {line_no}: bad weight"))?,
            ),
            None => None,
        };
        match (weighted, w.is_some()) {
            (None, has) => weighted = Some(has),
            (Some(want), has) if want != has => bail!(
                "line {line_no}: mixed weighted and unweighted lines in {}",
                edges.display()
            ),
            _ => {}
        }

        // Dense ids, batch-compatible: weighted lists use raw ids
        // (n = max + 1), unweighted lists intern by first appearance,
        // source before target.
        let (u, v) = if weighted == Some(true) {
            ensure!(
                u_ext < u32::MAX as u64 && v_ext < u32::MAX as u64,
                "line {line_no}: vertex id does not fit u32"
            );
            n = n.max(u_ext as usize + 1).max(v_ext as usize + 1);
            (u_ext as u32, v_ext as u32)
        } else {
            let mut get = |ext: u64| -> Result<u32> {
                if let Some(&id) = intern.get(&ext) {
                    return Ok(id);
                }
                ensure!(n < u32::MAX as usize, "vertex count does not fit u32");
                let id = n as u32;
                intern.insert(ext, id);
                n += 1;
                Ok(id)
            };
            let u = get(u_ext)?;
            let v = get(v_ext)?;
            (u, v)
        };

        dsu.grow(n);
        let (pu, pv) = (hasher.bucket(u as u64, k), hasher.bucket(v as u64, k));
        if pu == pv {
            dsu.union(u, v);
        }
        let wv = w.unwrap_or(1.0);
        spiller.push(pu, u, v, wv)?;
        if pv != pu {
            spiller.push(pv, u, v, wv)?;
        }
        num_edges += 1;
    }
    let weighted = weighted.unwrap_or(false);
    ensure!(n < u32::MAX as usize, "vertex count does not fit u32");
    spiller.flush_all()?;
    drop(span_pass0);

    // ---- Assign sub-graphs exactly like `subgraph::discover`:
    // indices per partition in order of each component's smallest
    // vertex; member lists ascend by global id.
    let mut part_of = vec![0u32; n];
    let mut sg_of = vec![0u32; n];
    let mut local_idx = vec![0u32; n];
    let mut members: Vec<Vec<Vec<u32>>> = vec![Vec::new(); k as usize];
    let mut root_index: HashMap<(u32, u32), u32> = HashMap::new();
    for v in 0..n as u32 {
        let p = hasher.bucket(v as u64, k);
        part_of[v as usize] = p;
        let root = dsu.find(v);
        let list = &mut members[p as usize];
        let idx = *root_index.entry((p, root)).or_insert_with(|| {
            list.push(Vec::new());
            (list.len() - 1) as u32
        });
        local_idx[v as usize] = list[idx as usize].len() as u32;
        list[idx as usize].push(v);
        sg_of[v as usize] = idx;
    }

    // ---- Pass 1: per host, concatenate its runs (arrival order) and
    // route every record to its sub-graph, then build and write the
    // partition. Only this host's edges are resident.
    let span_pass1 = rec.as_ref().map(|r| r.span("ingest_pass1", "ingest"));
    let mut subgraph_counts = Vec::with_capacity(k as usize);
    for p in 0..k {
        let count = members[p as usize].len();
        let mut local_edges: Vec<Vec<(u32, u32)>> = vec![Vec::new(); count];
        let mut local_weights: Vec<Vec<f32>> = vec![Vec::new(); count];
        let mut remote_out: Vec<Vec<RemoteRef>> = vec![Vec::new(); count];
        let mut remote_in: Vec<Vec<RemoteRef>> = vec![Vec::new(); count];
        for run in &spiller.runs[p as usize] {
            for_each_record(run, |u, v, w| {
                let (pu, pv) = (part_of[u as usize], part_of[v as usize]);
                let (su, sv) = (sg_of[u as usize], sg_of[v as usize]);
                if pu == p && pv == p {
                    // Same host ⇒ same component ⇒ same sub-graph.
                    local_edges[su as usize]
                        .push((local_idx[u as usize], local_idx[v as usize]));
                    if weighted {
                        local_weights[su as usize].push(w);
                    }
                } else if pu == p {
                    remote_out[su as usize].push(RemoteRef {
                        local: local_idx[u as usize],
                        target_global: v,
                        partition: pv,
                        subgraph: sv,
                        weight: w,
                    });
                } else {
                    remote_in[sv as usize].push(RemoteRef {
                        local: local_idx[v as usize],
                        target_global: u,
                        partition: pu,
                        subgraph: su,
                        weight: w,
                    });
                }
            })?;
        }
        // Normalize to `discover`'s enumeration order (stable sorts
        // keep arrival order within equal keys, which matches the
        // batch CSR sweeps).
        for refs in &mut remote_out {
            refs.sort_by_key(|r| r.local);
        }
        for refs in &mut remote_in {
            refs.sort_by_key(|r| (r.local, r.target_global));
        }

        let mut sgs = Vec::with_capacity(count);
        for i in 0..count {
            let vertices = std::mem::take(&mut members[p as usize][i]);
            let ws = if weighted {
                Some(std::mem::take(&mut local_weights[i]))
            } else {
                None
            };
            let local =
                Graph::from_edges(vertices.len(), &local_edges[i], ws, opts.directed)
                    .with_context(|| format!("partition {p} sub-graph {i}"))?;
            sgs.push(Subgraph {
                id: SubgraphId { partition: p, index: i as u32 },
                vertices,
                local,
                remote_out: std::mem::take(&mut remote_out[i]),
                remote_in: std::mem::take(&mut remote_in[i]),
                num_global_vertices: n as u64,
            });
        }
        write_partition_files(&store_root.join(format!("host{p}")), &sgs, opts.format)?;
        subgraph_counts.push(count as u32);
    }

    drop(span_pass1);
    drop(rec);

    let runs: u64 = spiller.runs.iter().map(|r| r.len() as u64).sum();
    fs::remove_dir_all(&tmp_dir)
        .with_context(|| format!("remove {}", tmp_dir.display()))?;

    let meta = StoreMeta {
        name: opts.name.clone(),
        num_vertices: n as u64,
        num_edges,
        directed: opts.directed,
        weighted,
        num_partitions: k,
        subgraph_counts: subgraph_counts.clone(),
        format: opts.format,
        generation: 0,
    };
    write_meta(&store_root.join("meta.txt"), &meta)?;

    let store = Store::open(store_root)?;
    let report = IngestReport {
        vertices: n as u64,
        edges: num_edges,
        subgraphs: subgraph_counts.iter().map(|&c| c as u64).sum(),
        spills: spiller.spills,
        runs,
        spilled_bytes: spiller.spilled_bytes,
        seconds: t0.elapsed().as_secs_f64(),
    };
    Ok((store, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gofs::{AttrProjection, LoadOptions};
    use crate::graph::{gen, io};
    use crate::partition::Partitioner;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("goffish_ingest_{name}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    /// Batch-build and stream-build the same edge list; compare every
    /// store file byte-for-byte.
    fn assert_parity(g: &Graph, hosts: u32, format: SliceFormat, spill: usize, dir: &Path) {
        let file = dir.join("edges.tsv");
        io::write_edge_list(g, &file).unwrap();

        let g2 = io::read_edge_list(&file, g.directed()).unwrap();
        let parts = HashPartitioner::new(1).partition(&g2, hosts as usize);
        let batch_root = dir.join("batch");
        Store::create_with_format(&batch_root, "graph", &g2, &parts, format).unwrap();

        let stream_root = dir.join("stream");
        let (store, report) = ingest_edge_list(
            &file,
            &stream_root,
            &IngestOptions {
                hosts,
                format,
                directed: g.directed(),
                spill_buffer: spill,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(report.vertices, g2.num_vertices() as u64);
        assert_eq!(report.edges, g2.num_edges() as u64);
        assert!(!stream_root.join(".ingest").exists());

        assert_eq!(
            fs::read_to_string(batch_root.join("meta.txt")).unwrap(),
            fs::read_to_string(stream_root.join("meta.txt")).unwrap()
        );
        for p in 0..hosts {
            let host = format!("host{p}");
            let ls = |root: &Path| -> Vec<String> {
                let mut v: Vec<String> = fs::read_dir(root.join(&host))
                    .unwrap()
                    .map(|e| e.unwrap().file_name().into_string().unwrap())
                    .collect();
                v.sort();
                v
            };
            let names = ls(&batch_root);
            assert_eq!(names, ls(&stream_root), "{host} file sets differ");
            for name in &names {
                assert_eq!(
                    fs::read(batch_root.join(&host).join(name)).unwrap(),
                    fs::read(stream_root.join(&host).join(name)).unwrap(),
                    "{host}/{name} differs"
                );
            }
        }
        // And the loaded view round-trips.
        let (dg, _, _) = store
            .load_all_with(&LoadOptions {
                attributes: AttrProjection::All,
                ..Default::default()
            })
            .unwrap();
        assert_eq!(dg.num_global_vertices, g2.num_vertices() as u64);
        let loaded: usize =
            dg.partitions.iter().flatten().map(|s| s.vertices.len()).sum();
        assert_eq!(loaded, g2.num_vertices());
    }

    #[test]
    fn streamed_unweighted_store_matches_batch_bytes() {
        let dir = tmp("unweighted");
        let g = gen::road(5, 0.9, 0.05, 11);
        // 64-byte spill buffer ≪ input: forces many spills.
        assert_parity(&g, 3, SliceFormat::V3Packed, 64, &dir);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn streamed_weighted_store_matches_batch_bytes_v2() {
        let dir = tmp("weighted");
        let g = gen::with_random_weights(&gen::road(4, 0.95, 0.08, 3), 0.5, 4.0, 9);
        assert_parity(&g, 2, SliceFormat::V2Columnar, 48, &dir);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tiny_spill_buffer_spills_per_edge() {
        let dir = tmp("spill");
        let g = gen::chain(20);
        let file = dir.join("edges.tsv");
        io::write_edge_list(&g, &file).unwrap();
        let (_, report) = ingest_edge_list(
            &file,
            &dir.join("s"),
            &IngestOptions { hosts: 2, spill_buffer: 1, ..Default::default() },
        )
        .unwrap();
        // Cap of one record: every push flushes.
        assert!(report.spills >= report.edges, "{report:?}");
        assert!(report.runs > 2, "{report:?}");
        assert_eq!(report.spilled_bytes % REC_BYTES as u64, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn traced_ingest_records_both_passes() {
        let dir = tmp("traced");
        let file = dir.join("edges.tsv");
        io::write_edge_list(&gen::chain(12), &file).unwrap();
        let trace = crate::obs::trace::Tracer::enabled();
        ingest_edge_list(
            &file,
            &dir.join("s"),
            &IngestOptions { trace: trace.clone(), ..Default::default() },
        )
        .unwrap();
        let events = trace.sink().unwrap().events();
        let names: Vec<&str> = events.iter().map(|e| e.name).collect();
        assert_eq!(names.iter().filter(|n| **n == "ingest_pass0").count(), 1, "{names:?}");
        assert_eq!(names.iter().filter(|n| **n == "ingest_pass1").count(), 1, "{names:?}");
        // Pass 0 finishes before pass 1 starts (sequential phases).
        let p0 = events.iter().find(|e| e.name == "ingest_pass0").unwrap();
        let p1 = events.iter().find(|e| e.name == "ingest_pass1").unwrap();
        assert!(p0.ts_us + p0.dur_us <= p1.ts_us);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn csv_comments_and_blank_lines_accepted() {
        let dir = tmp("csv");
        let file = dir.join("edges.csv");
        fs::write(&file, "# a comment\n0,1\n\n1,2\n2 , 3\n").unwrap();
        let (store, report) =
            ingest_edge_list(&file, &dir.join("s"), &IngestOptions::default()).unwrap();
        assert_eq!(report.vertices, 4);
        assert_eq!(report.edges, 3);
        assert_eq!(store.meta().num_vertices, 4);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_lines_name_their_line_number() {
        let dir = tmp("malformed");
        let cases = [
            ("0 1\nx 2\n", "line 2: bad src"),
            ("0\n", "line 1: missing dst"),
            ("# c\n0 1\n1 y\n", "line 3: bad dst"),
            ("0 1 zz\n", "line 1: bad weight"),
            ("0 1\n1 2 0.5\n", "line 2: mixed weighted and unweighted"),
            ("0 1 0.5\n1 2\n", "line 2: mixed weighted and unweighted"),
        ];
        for (i, (text, want)) in cases.iter().enumerate() {
            let file = dir.join(format!("edges{i}.tsv"));
            fs::write(&file, text).unwrap();
            let err = ingest_edge_list(&file, &dir.join(format!("s{i}")), &IngestOptions::default())
                .unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(want), "{msg:?} missing {want:?}");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn refuses_nonempty_store_root() {
        let dir = tmp("nonempty");
        let file = dir.join("edges.tsv");
        fs::write(&file, "0 1\n").unwrap();
        let root = dir.join("s");
        fs::create_dir_all(&root).unwrap();
        fs::write(root.join("stray"), "x").unwrap();
        let err = ingest_edge_list(&file, &root, &IngestOptions::default()).unwrap_err();
        assert!(format!("{err:#}").contains("write-once"), "{err:#}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn weighted_raw_ids_create_isolated_singletons() {
        // Weighted lists use raw ids; id 3 never appears, so it becomes
        // an isolated singleton sub-graph — same as the batch reader.
        let dir = tmp("rawids");
        let file = dir.join("edges.tsv");
        fs::write(&file, "0 1 1.0\n4 5 2.0\n").unwrap();
        let (store, report) =
            ingest_edge_list(&file, &dir.join("s"), &IngestOptions { hosts: 1, ..Default::default() })
                .unwrap();
        assert_eq!(report.vertices, 6);
        assert!(store.meta().weighted);
        let (dg, _, _) = store.load_all_with(&LoadOptions::default()).unwrap();
        // Components {0,1}, {2}, {3}, {4,5}.
        assert_eq!(dg.num_subgraphs(), 4);
        let _ = fs::remove_dir_all(&dir);
    }
}
