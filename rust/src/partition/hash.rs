//! Hash partitioner — the Pregel/Giraph default vertex placement.
//!
//! Vertices are scattered by a multiplicative hash of their id. This is
//! exactly the "naïve vertex distribution" the paper's §1 calls out: it
//! balances vertex counts almost perfectly but cuts nearly every edge,
//! which is what makes the vertex-centric baseline communication-bound.

use crate::graph::csr::Graph;

use super::types::{Partitioner, Partitioning};

pub struct HashPartitioner {
    seed: u64,
}

impl HashPartitioner {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    /// Bucket of a single dense vertex id — the online form of
    /// [`Partitioner::partition`], usable before any [`Graph`] exists.
    /// The streaming ingest path assigns vertices with this as edges
    /// arrive, and `Store::append` places new vertices with it; both
    /// must agree bit-for-bit with the batch partitioner, so this *is*
    /// the batch implementation.
    pub fn bucket(&self, v: u64, k: u32) -> u32 {
        let mut x = v ^ self.seed;
        // Finalizer from SplitMix64: well-mixed buckets.
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
        x ^= x >> 31;
        (x % k as u64) as u32
    }
}

impl Default for HashPartitioner {
    fn default() -> Self {
        Self::new(0x9E3779B97F4A7C15)
    }
}

impl Partitioner for HashPartitioner {
    fn partition(&self, g: &Graph, k: usize) -> Partitioning {
        assert!(k >= 1);
        let assignment = (0..g.num_vertices() as u64)
            .map(|v| self.bucket(v, k as u32))
            .collect();
        Partitioning::new(k, assignment)
    }

    fn name(&self) -> &'static str {
        "hash"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn covers_all_vertices_balanced() {
        let g = gen::grid(30, 30);
        let p = HashPartitioner::default().partition(&g, 4);
        assert_eq!(p.num_vertices(), 900);
        let m = p.metrics(&g);
        assert!(m.imbalance < 1.15, "imbalance={}", m.imbalance);
    }

    #[test]
    fn cuts_most_edges_on_local_graph() {
        // On a lattice, hashing destroys locality: expect ~ (k-1)/k cut.
        let g = gen::grid(30, 30);
        let p = HashPartitioner::default().partition(&g, 4);
        let m = p.metrics(&g);
        assert!(m.cut_fraction > 0.5, "cut={}", m.cut_fraction);
    }

    #[test]
    fn deterministic_for_seed() {
        let g = gen::chain(100);
        let a = HashPartitioner::new(5).partition(&g, 3);
        let b = HashPartitioner::new(5).partition(&g, 3);
        assert_eq!(a.assignment(), b.assignment());
    }

    #[test]
    fn bucket_matches_batch_partition() {
        let g = gen::chain(64);
        let p = HashPartitioner::new(7).partition(&g, 5);
        let h = HashPartitioner::new(7);
        for v in 0..64u64 {
            assert_eq!(h.bucket(v, 5), p.of(v as u32));
        }
    }

    #[test]
    fn k_equals_one() {
        let g = gen::chain(10);
        let p = HashPartitioner::default().partition(&g, 1);
        assert!(p.assignment().iter().all(|&x| x == 0));
        assert_eq!(p.metrics(&g).edge_cut, 0);
    }
}
