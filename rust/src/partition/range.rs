//! Range partitioner: contiguous id blocks.
//!
//! For generators whose ids have spatial meaning (the road lattice), this
//! is a surprisingly strong locality baseline; for hashed/arbitrary ids it
//! degenerates. Included as the third arm of the partitioning ablation.

use crate::graph::csr::Graph;

use super::types::{Partitioner, Partitioning};

#[derive(Default)]
pub struct RangePartitioner;

impl Partitioner for RangePartitioner {
    fn partition(&self, g: &Graph, k: usize) -> Partitioning {
        assert!(k >= 1);
        let n = g.num_vertices();
        let per = n.div_ceil(k).max(1);
        let assignment = (0..n).map(|v| ((v / per) as u32).min(k as u32 - 1)).collect();
        Partitioning::new(k, assignment)
    }

    fn name(&self) -> &'static str {
        "range"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn contiguous_blocks() {
        let g = gen::chain(10);
        let p = RangePartitioner.partition(&g, 2);
        assert_eq!(p.assignment(), &[0, 0, 0, 0, 0, 1, 1, 1, 1, 1]);
        // Chain cut by range partitioning = k-1 edges.
        assert_eq!(p.metrics(&g).edge_cut, 1);
    }

    #[test]
    fn uneven_division() {
        let g = gen::chain(7);
        let p = RangePartitioner.partition(&g, 3);
        let sizes = p.sizes();
        assert_eq!(sizes.iter().sum::<usize>(), 7);
        assert!(sizes.iter().all(|&s| s >= 1));
    }

    #[test]
    fn k_larger_than_n() {
        let g = gen::chain(3);
        let p = RangePartitioner.partition(&g, 8);
        assert_eq!(p.num_vertices(), 3);
        // All assignments within range.
        assert!(p.assignment().iter().all(|&a| a < 8));
    }
}
