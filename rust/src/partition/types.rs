//! Partitioning types and quality metrics.

use crate::graph::csr::{Graph, VertexId};

/// A k-way assignment of vertices to partitions (hosts).
#[derive(Clone, Debug)]
pub struct Partitioning {
    k: usize,
    assignment: Vec<u32>,
}

impl Partitioning {
    pub fn new(k: usize, assignment: Vec<u32>) -> Self {
        debug_assert!(assignment.iter().all(|&p| (p as usize) < k));
        Self { k, assignment }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn num_vertices(&self) -> usize {
        self.assignment.len()
    }

    /// Partition that vertex `v` lives on.
    pub fn of(&self, v: VertexId) -> u32 {
        self.assignment[v as usize]
    }

    pub fn assignment(&self) -> &[u32] {
        &self.assignment
    }

    /// Vertices of partition `p`, in id order.
    pub fn vertices_of(&self, p: u32) -> Vec<VertexId> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|(_, &a)| a == p)
            .map(|(v, _)| v as VertexId)
            .collect()
    }

    /// Vertex count per partition.
    pub fn sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.k];
        for &p in &self.assignment {
            sizes[p as usize] += 1;
        }
        sizes
    }

    /// Quality metrics against the graph that was partitioned.
    pub fn metrics(&self, g: &Graph) -> PartitionMetrics {
        assert_eq!(g.num_vertices(), self.assignment.len());
        let mut cut = 0usize;
        for (u, v, _) in g.edges() {
            if self.of(u) != self.of(v) {
                cut += 1;
            }
        }
        let sizes = self.sizes();
        let max = *sizes.iter().max().unwrap_or(&0);
        let ideal = (g.num_vertices() as f64 / self.k as f64).max(1.0);
        PartitionMetrics {
            edge_cut: cut,
            cut_fraction: if g.num_edges() == 0 {
                0.0
            } else {
                cut as f64 / g.num_edges() as f64
            },
            imbalance: max as f64 / ideal,
            sizes,
        }
    }
}

/// Edge-cut and balance quality of a partitioning.
#[derive(Clone, Debug)]
pub struct PartitionMetrics {
    /// Number of edges crossing partitions.
    pub edge_cut: usize,
    /// `edge_cut / num_edges`.
    pub cut_fraction: f64,
    /// `max partition size / ideal size` (1.0 = perfectly balanced).
    pub imbalance: f64,
    pub sizes: Vec<usize>,
}

/// A k-way partitioning strategy.
pub trait Partitioner {
    fn partition(&self, g: &Graph, k: usize) -> Partitioning;
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn metrics_on_manual_split() {
        let g = gen::chain(4); // edges 0-1, 1-2, 2-3
        let p = Partitioning::new(2, vec![0, 0, 1, 1]);
        let m = p.metrics(&g);
        assert_eq!(m.edge_cut, 1); // only 1-2 crosses
        assert_eq!(m.sizes, vec![2, 2]);
        assert!((m.imbalance - 1.0).abs() < 1e-9);
        assert!((m.cut_fraction - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn vertices_of_ordered() {
        let p = Partitioning::new(2, vec![1, 0, 1, 0]);
        assert_eq!(p.vertices_of(0), vec![1, 3]);
        assert_eq!(p.vertices_of(1), vec![0, 2]);
    }

    #[test]
    fn worst_case_imbalance() {
        let g = gen::chain(4);
        let p = Partitioning::new(2, vec![0, 0, 0, 0]);
        let m = p.metrics(&g);
        assert_eq!(m.edge_cut, 0);
        assert!((m.imbalance - 2.0).abs() < 1e-9);
    }
}
