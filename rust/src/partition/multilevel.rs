//! METIS-like multilevel k-way partitioner.
//!
//! Three phases, following Karypis & Kumar's scheme (the paper partitions
//! with METIS; DESIGN.md §3 documents this substitution):
//!
//! 1. **Coarsening** — repeated heavy-edge matching collapses matched
//!    vertex pairs into super-vertices (edge weights accumulate, vertex
//!    weights add) until the graph is small or shrinkage stalls.
//! 2. **Initial partitioning** — greedy graph growing on the coarsest
//!    graph: BFS-grow each part up to the balanced vertex-weight budget,
//!    seeding each growth from the least-attached remaining vertex.
//! 3. **Uncoarsening + refinement** — project the assignment back level
//!    by level, running boundary Fiduccia–Mattheyses passes (move a
//!    boundary vertex to the neighbouring part with the best cut gain,
//!    subject to a balance cap) at each level.

use std::collections::BTreeMap;

use crate::graph::csr::Graph;

use super::types::{Partitioner, Partitioning};

/// Stop coarsening when at most `COARSEST_PER_PART * k` vertices remain.
const COARSEST_PER_PART: usize = 30;
/// Give up coarsening when a level shrinks less than this factor.
const MIN_SHRINK: f64 = 0.95;
/// Max refinement passes per level.
const FM_PASSES: usize = 4;
/// Allowed imbalance during refinement (max part / ideal part).
const BALANCE_CAP: f64 = 1.05;

/// Working representation during coarsening: weighted adjacency maps.
struct Level {
    /// adj[v] = neighbour -> accumulated edge weight
    adj: Vec<BTreeMap<u32, u64>>,
    /// vertex weights (number of original vertices collapsed)
    vw: Vec<u64>,
    /// map from this level's vertices to the coarser level's vertices
    /// (filled when the next level is built)
    to_coarse: Vec<u32>,
}

pub struct MultilevelPartitioner {
    seed: u64,
}

impl MultilevelPartitioner {
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }
}

impl Default for MultilevelPartitioner {
    fn default() -> Self {
        Self::new(1)
    }
}

impl Partitioner for MultilevelPartitioner {
    fn partition(&self, g: &Graph, k: usize) -> Partitioning {
        assert!(k >= 1);
        let n = g.num_vertices();
        if k == 1 || n == 0 {
            return Partitioning::new(k, vec![0; n]);
        }
        if n <= k {
            return Partitioning::new(k, (0..n as u32).map(|v| v % k as u32).collect());
        }

        // Level 0 from the CSR (undirected, deduped, weight 1 per edge).
        let mut levels: Vec<Level> = vec![level_from_graph(g)];

        // Phase 1: coarsen.
        loop {
            let cur = levels.last().unwrap();
            let cur_n = cur.adj.len();
            if cur_n <= COARSEST_PER_PART * k {
                break;
            }
            let (next, mapping) = coarsen_once(cur, self.seed ^ levels.len() as u64);
            let shrink = next.adj.len() as f64 / cur_n as f64;
            levels.last_mut().unwrap().to_coarse = mapping;
            if shrink > MIN_SHRINK {
                // Matching stalled (e.g. star graphs) — stop coarsening.
                levels.push(next);
                break;
            }
            levels.push(next);
        }

        // Phase 2: initial partitioning on the coarsest level.
        let coarsest = levels.last().unwrap();
        let mut assign = grow_initial(coarsest, k, self.seed);
        refine(coarsest, &mut assign, k);

        // Phase 3: project back and refine at each level.
        for li in (0..levels.len() - 1).rev() {
            let fine = &levels[li];
            let mut fine_assign = vec![0u32; fine.adj.len()];
            for (v, a) in fine_assign.iter_mut().enumerate() {
                *a = assign[fine.to_coarse[v] as usize];
            }
            refine(fine, &mut fine_assign, k);
            assign = fine_assign;
        }

        Partitioning::new(k, assign)
    }

    fn name(&self) -> &'static str {
        "multilevel"
    }
}

fn level_from_graph(g: &Graph) -> Level {
    let n = g.num_vertices();
    let mut adj: Vec<BTreeMap<u32, u64>> = vec![BTreeMap::new(); n];
    for (u, v, _) in g.edges() {
        if u == v {
            continue;
        }
        *adj[u as usize].entry(v).or_insert(0) += 1;
        *adj[v as usize].entry(u).or_insert(0) += 1;
    }
    Level { adj, vw: vec![1; n], to_coarse: Vec::new() }
}

/// One round of heavy-edge matching; returns the coarser level and the
/// fine->coarse vertex mapping.
fn coarsen_once(level: &Level, seed: u64) -> (Level, Vec<u32>) {
    let n = level.adj.len();
    let mut order: Vec<u32> = (0..n as u32).collect();
    let mut rng = crate::util::rng::Rng::new(seed);
    rng.shuffle(&mut order);

    let mut mate = vec![u32::MAX; n];
    for &v in &order {
        if mate[v as usize] != u32::MAX {
            continue;
        }
        // Heaviest unmatched neighbour.
        let mut best: Option<(u64, u32)> = None;
        for (&u, &w) in &level.adj[v as usize] {
            if mate[u as usize] == u32::MAX && u != v {
                let cand = (w, u);
                if best.is_none_or(|b| cand > b) {
                    best = Some(cand);
                }
            }
        }
        match best {
            Some((_, u)) => {
                mate[v as usize] = u;
                mate[u as usize] = v;
            }
            None => mate[v as usize] = v, // self-matched (stays single)
        }
    }

    // Assign coarse ids.
    let mut to_coarse = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n as u32 {
        if to_coarse[v as usize] != u32::MAX {
            continue;
        }
        let m = mate[v as usize];
        to_coarse[v as usize] = next;
        if m != v && m != u32::MAX {
            to_coarse[m as usize] = next;
        }
        next += 1;
    }

    // Build the coarse level.
    let cn = next as usize;
    let mut adj: Vec<BTreeMap<u32, u64>> = vec![BTreeMap::new(); cn];
    let mut vw = vec![0u64; cn];
    for v in 0..n {
        let cv = to_coarse[v] as usize;
        vw[cv] += level.vw[v];
        for (&u, &w) in &level.adj[v] {
            let cu = to_coarse[u as usize];
            if cu as usize != cv {
                *adj[cv].entry(cu).or_insert(0) += w;
            }
        }
    }
    // Each undirected edge was visited from both ends: halve the weights.
    for m in &mut adj {
        for w in m.values_mut() {
            *w /= 2;
        }
    }
    (Level { adj, vw, to_coarse: Vec::new() }, to_coarse)
}

/// Greedy graph growing: BFS-grow part after part up to the weight budget.
fn grow_initial(level: &Level, k: usize, seed: u64) -> Vec<u32> {
    let n = level.adj.len();
    let total_w: u64 = level.vw.iter().sum();
    let budget = total_w.div_ceil(k as u64);
    let mut assign = vec![u32::MAX; n];
    let mut rng = crate::util::rng::Rng::new(seed ^ 0xBEEF);
    let mut unassigned = n;

    for part in 0..k as u32 {
        if unassigned == 0 {
            break;
        }
        let is_last = part as usize == k - 1;
        // Seed: a random unassigned vertex.
        let mut seed_v = rng.index(n);
        while assign[seed_v] != u32::MAX {
            seed_v = (seed_v + 1) % n;
        }
        let mut weight = 0u64;
        let mut frontier = std::collections::VecDeque::new();
        frontier.push_back(seed_v as u32);
        while weight < budget || is_last {
            let v = match frontier.pop_front() {
                Some(v) => v,
                None => {
                    // Disconnected remainder: jump to a fresh seed.
                    match (0..n).find(|&x| assign[x] == u32::MAX) {
                        Some(x) if weight < budget || is_last => x as u32,
                        _ => break,
                    }
                }
            };
            if assign[v as usize] != u32::MAX {
                continue;
            }
            assign[v as usize] = part;
            weight += level.vw[v as usize];
            unassigned -= 1;
            if unassigned == 0 {
                break;
            }
            for &u in level.adj[v as usize].keys() {
                if assign[u as usize] == u32::MAX {
                    frontier.push_back(u);
                }
            }
            if weight >= budget && !is_last {
                break;
            }
        }
    }
    // Sweep any stragglers into the lightest part.
    for v in 0..n {
        if assign[v] == u32::MAX {
            let mut pw = vec![0u64; k];
            for x in 0..n {
                if assign[x] != u32::MAX {
                    pw[assign[x] as usize] += level.vw[x];
                }
            }
            let lightest = (0..k).min_by_key(|&p| pw[p]).unwrap() as u32;
            assign[v] = lightest;
        }
    }
    assign
}

/// Boundary FM refinement: greedy positive-gain moves under a balance cap.
fn refine(level: &Level, assign: &mut [u32], k: usize) {
    let n = level.adj.len();
    let total_w: u64 = level.vw.iter().sum();
    let ideal = (total_w as f64 / k as f64).max(1.0);
    let cap = (ideal * BALANCE_CAP).ceil() as u64;

    let mut part_w = vec![0u64; k];
    for v in 0..n {
        part_w[assign[v] as usize] += level.vw[v];
    }

    for _ in 0..FM_PASSES {
        let mut moved = 0usize;
        for v in 0..n {
            let from = assign[v];
            if level.adj[v].is_empty() {
                continue;
            }
            // Connectivity of v to each adjacent part.
            let mut conn: BTreeMap<u32, i64> = BTreeMap::new();
            for (&u, &w) in &level.adj[v] {
                *conn.entry(assign[u as usize]).or_insert(0) += w as i64;
            }
            let own = *conn.get(&from).unwrap_or(&0);
            let best = conn
                .iter()
                .filter(|(&p, _)| p != from)
                .max_by_key(|(_, &w)| w);
            if let Some((&to, &w_to)) = best {
                let gain = w_to - own;
                let fits = part_w[to as usize] + level.vw[v] <= cap;
                let frees = part_w[from as usize] > level.vw[v];
                if gain > 0 && fits && frees {
                    part_w[from as usize] -= level.vw[v];
                    part_w[to as usize] += level.vw[v];
                    assign[v] = to;
                    moved += 1;
                }
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::hash::HashPartitioner;

    #[test]
    fn covers_all_vertices_once() {
        let g = gen::grid(20, 20);
        let p = MultilevelPartitioner::default().partition(&g, 4);
        assert_eq!(p.num_vertices(), 400);
        assert_eq!(p.sizes().iter().sum::<usize>(), 400);
    }

    #[test]
    fn beats_hash_on_lattice_cut() {
        let g = gen::grid(40, 40);
        let ml = MultilevelPartitioner::default().partition(&g, 4).metrics(&g);
        let h = HashPartitioner::default().partition(&g, 4).metrics(&g);
        assert!(
            ml.cut_fraction < h.cut_fraction / 3.0,
            "multilevel {} vs hash {}",
            ml.cut_fraction,
            h.cut_fraction
        );
    }

    #[test]
    fn balance_within_cap() {
        let g = gen::grid(30, 30);
        for k in [2, 3, 4, 8] {
            let m = MultilevelPartitioner::default().partition(&g, k).metrics(&g);
            assert!(m.imbalance < 1.3, "k={k} imbalance={}", m.imbalance);
        }
    }

    #[test]
    fn chain_cut_near_optimal() {
        let g = gen::chain(1000);
        let m = MultilevelPartitioner::default().partition(&g, 4).metrics(&g);
        // Optimal cut is 3; accept a small constant factor.
        assert!(m.edge_cut <= 12, "cut={}", m.edge_cut);
    }

    #[test]
    fn handles_disconnected_graph() {
        // Two separate grids glued as one vertex set.
        let mut b = crate::graph::GraphBuilder::new(false);
        b.reserve_vertices(200);
        for i in 0..99 {
            b.add_edge(i, i + 1);
        }
        for i in 100..199 {
            b.add_edge(i, i + 1);
        }
        let g = b.build().unwrap();
        let p = MultilevelPartitioner::default().partition(&g, 2);
        let m = p.metrics(&g);
        assert!(m.edge_cut <= 4, "cut={}", m.edge_cut);
        assert!(m.imbalance < 1.3, "imbalance={}", m.imbalance);
    }

    #[test]
    fn k_one_and_tiny_graphs() {
        let g = gen::chain(5);
        let p1 = MultilevelPartitioner::default().partition(&g, 1);
        assert!(p1.assignment().iter().all(|&a| a == 0));
        let g2 = gen::chain(3);
        let p8 = MultilevelPartitioner::default().partition(&g2, 8);
        assert_eq!(p8.num_vertices(), 3);
    }

    #[test]
    fn star_graph_does_not_hang() {
        let g = gen::star(500);
        let p = MultilevelPartitioner::default().partition(&g, 4);
        assert_eq!(p.sizes().iter().sum::<usize>(), 500);
    }

    #[test]
    fn deterministic_for_seed() {
        let g = gen::grid(15, 15);
        let a = MultilevelPartitioner::new(9).partition(&g, 3);
        let b = MultilevelPartitioner::new(9).partition(&g, 3);
        assert_eq!(a.assignment(), b.assignment());
    }
}
