//! k-way graph partitioners.
//!
//! GoFS distributes one partition per host (paper §4.1). The paper uses
//! METIS; the offline testbed carries [`multilevel`], an in-crate
//! METIS-like multilevel partitioner (heavy-edge-matching coarsening →
//! greedy growing → FM boundary refinement) with the same objective:
//! balance vertices per partition, minimise edge cut. [`hash`] is the
//! Giraph default (random vertex hashing) used by the baseline engine,
//! and [`range`] is the contiguous-id strawman.

pub mod types;
pub mod hash;
pub mod range;
pub mod multilevel;

pub use hash::HashPartitioner;
pub use multilevel::MultilevelPartitioner;
pub use range::RangePartitioner;
pub use types::{PartitionMetrics, Partitioner, Partitioning};
