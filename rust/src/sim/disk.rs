//! Spinning-disk cost model (SATA HDD of the paper's testbed).

/// Sequential-read oriented disk model: each file costs one seek plus
/// streaming at the sequential bandwidth — exactly the trade GoFS's
//  slice layout optimises ("balance the disk latency (# of unique files
//  read) against sequential bytes read", paper §4.3).
#[derive(Clone, Copy, Debug)]
pub struct DiskModel {
    /// Average seek + rotational latency per file open (seconds).
    pub seek_seconds: f64,
    /// Sequential read bandwidth (bytes/second).
    pub seq_bytes_per_sec: f64,
    /// Per-record CPU cost of materialising storage bytes into memory
    /// objects (seconds/record) — deserialization, allocation. This is
    /// what blows up Giraph's load on the TR mega-hub (paper §6.3).
    pub per_record_seconds: f64,
}

impl Default for DiskModel {
    /// 1 TB SATA HDD circa 2013: ~10 ms seek, ~100 MB/s sequential.
    fn default() -> Self {
        Self {
            seek_seconds: 0.010,
            seq_bytes_per_sec: 100e6,
            per_record_seconds: 2e-7,
        }
    }
}

/// Cost of skipping forward inside an already-open file, relative to a
/// cold per-file seek: no directory lookup and a short head movement.
const INTRA_FILE_SEEK_FRACTION: f64 = 0.25;

impl DiskModel {
    /// Time to read `files` files totalling `bytes`, materialising
    /// `records` objects.
    pub fn read_seconds(&self, files: u64, bytes: u64, records: u64) -> f64 {
        self.seek_seconds * files as f64
            + bytes as f64 / self.seq_bytes_per_sec
            + self.per_record_seconds * records as f64
    }

    /// Projected read over sectioned files: the section directory lets
    /// a reader seek past sections it does not need (unwanted
    /// attribute columns, weights on an unweighted run), so `bytes`
    /// counts only the sections actually streamed and each skipped
    /// byte-run costs an intra-file seek instead of bandwidth.
    /// `skipped_runs` counts *contiguous* skipped ranges — adjacent
    /// skipped sections coalesce into one head movement, exactly as
    /// the GoFS v3 loader coalesces adjacent wanted sections into one
    /// read.
    pub fn projected_read_seconds(
        &self,
        files: u64,
        bytes: u64,
        records: u64,
        skipped_runs: u64,
    ) -> f64 {
        self.read_seconds(files, bytes, records)
            + self.seek_seconds * INTRA_FILE_SEEK_FRACTION * skipped_runs as f64
    }

    /// Projected read of GoFS v3 packed partition files: one cold seek
    /// per partition file (not per slice — the whole point of the
    /// packed layout), the prelude + directory streamed up front
    /// (`dir_bytes`), then the wanted sections streamed with an
    /// intra-file seek per skipped run. This is the "skip 9 of 10
    /// attribute sections in place" scenario the packed format exists
    /// for; compare with [`DiskModel::read_seconds`] over one file per
    /// slice to see the seek budget collapse.
    pub fn packed_read_seconds(
        &self,
        files: u64,
        dir_bytes: u64,
        bytes: u64,
        records: u64,
        skipped_runs: u64,
    ) -> f64 {
        self.projected_read_seconds(files, bytes, records, skipped_runs)
            + dir_bytes as f64 / self.seq_bytes_per_sec
    }

    /// Memory-mapped projected read of a GoFS v3 packed partition file:
    /// one cold seek to open and map, the prelude + directory faulted in
    /// (`dir_bytes`), then only the wanted sections' pages faulted
    /// (`bytes` — directory-listed section lengths, matching
    /// `LoadStats.bytes` accounting). Unlike
    /// [`DiskModel::packed_read_seconds`] there is **no intra-file seek
    /// charge per skipped run**: unwanted sections are never faulted at
    /// all — the page cache simply skips those offsets — so the skip
    /// penalty the seek+read path pays disappears. Records still pay the
    /// per-record materialisation cost (checksum + decode are unchanged).
    pub fn mmap_read_seconds(&self, dir_bytes: u64, bytes: u64, records: u64) -> f64 {
        self.seek_seconds
            + (dir_bytes + bytes) as f64 / self.seq_bytes_per_sec
            + self.per_record_seconds * records as f64
    }

    /// Streaming-ingest cost (`crate::ingest`): edges are parsed once,
    /// spilled to per-host run files whenever the `spill_buffer` byte
    /// budget fills, re-read per host in pass 1, and written out as
    /// `hosts` partition files. Writes are modelled at the sequential
    /// bandwidth like reads (HDD write ≈ read for streaming), each run
    /// file costs a cold seek twice (write, read back), and both passes
    /// pay the per-record CPU cost (parse, then CSR build). The term
    /// that moves with the knob: run-file count ≈ `hosts ×
    /// ⌈spilled/spill_buffer⌉`, so halving the buffer doubles the seek
    /// budget while the streamed bytes stay fixed — the bounded-memory
    /// trade the `ingest_throughput` bench measures on real disks.
    pub fn ingest_seconds(&self, edges: u64, hosts: u64, spill_buffer: u64) -> f64 {
        // Spill record width (`crate::ingest`'s u32,u32,f32 layout).
        const REC_BYTES: u64 = 12;
        let spilled = edges * REC_BYTES;
        let trips = spilled.div_ceil(spill_buffer.max(REC_BYTES));
        let runs = hosts.max(1) * trips.max(1);
        // Pass 0: parse every line, write every run file.
        let pass0 = self.per_record_seconds * edges as f64
            + self.read_seconds(runs, spilled, 0);
        // Pass 1: read every run back, build CSR, write partitions.
        let pass1 = self.read_seconds(runs, spilled, edges)
            + self.read_seconds(hosts.max(1), spilled, 0);
        pass0 + pass1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_time_components() {
        let d = DiskModel::default();
        // 1 file, 100 MB, no records: 10ms + 1s.
        let t = d.read_seconds(1, 100_000_000, 0);
        assert!((t - 1.01).abs() < 1e-9, "{t}");
    }

    #[test]
    fn many_small_files_pay_seeks() {
        let d = DiskModel::default();
        let few = d.read_seconds(1, 1_000_000, 0);
        let many = d.read_seconds(1000, 1_000_000, 0);
        assert!(many > few * 100.0);
    }

    #[test]
    fn projected_read_beats_full_read() {
        // 100 slice files of 1 MB each; a projection streams 1/10 of the
        // bytes and pays one intra-file skip per file instead.
        let d = DiskModel::default();
        let full = d.read_seconds(100, 100_000_000, 0);
        let projected = d.projected_read_seconds(100, 10_000_000, 0, 100);
        assert!(projected < full, "projected={projected} full={full}");
        // Skips are not free: same bytes + skips costs more than plain.
        let plain = d.read_seconds(100, 10_000_000, 0);
        assert!(projected > plain);
    }

    #[test]
    fn packed_projection_beats_per_file_projection() {
        // 100 sub-graphs × (1 topo + 1 attr) as separate files vs one
        // packed partition file with the same payload: the packed read
        // pays one cold seek, a 50 KB directory, and 100 intra-file
        // skips instead of 200 cold seeks.
        let d = DiskModel::default();
        let per_file = d.read_seconds(200, 20_000_000, 0);
        let packed = d.packed_read_seconds(1, 50_000, 20_000_000, 0, 100);
        assert!(packed < per_file, "packed={packed} per_file={per_file}");
        // The directory is not free: same shape minus the directory
        // costs strictly less.
        assert!(packed > d.projected_read_seconds(1, 20_000_000, 0, 100));
    }

    #[test]
    fn mmap_projection_beats_seek_read_projection() {
        // Same packed projection as above — 1 file, 50 KB directory,
        // 20 MB of wanted sections — but mapped: the 100 skipped runs
        // cost nothing because their pages are never faulted.
        let d = DiskModel::default();
        let seek_read = d.packed_read_seconds(1, 50_000, 20_000_000, 0, 100);
        let mapped = d.mmap_read_seconds(50_000, 20_000_000, 0);
        assert!(mapped < seek_read, "mapped={mapped} seek_read={seek_read}");
        // With zero skipped runs the two paths collapse to the same
        // cost: one seek, directory + wanted bytes streamed.
        let no_skips = d.packed_read_seconds(1, 50_000, 20_000_000, 0, 0);
        assert!((mapped - no_skips).abs() < 1e-12, "{mapped} vs {no_skips}");
        // Records cost the same on both paths — decode is unchanged.
        let recs = d.mmap_read_seconds(50_000, 20_000_000, 1_000_000)
            - d.mmap_read_seconds(50_000, 20_000_000, 0);
        assert!((recs - d.per_record_seconds * 1e6).abs() < 1e-9);
    }

    #[test]
    fn ingest_cost_trades_buffer_for_seeks() {
        let d = DiskModel::default();
        // Shrinking the spill buffer only ever adds seeks: cost is
        // monotonically non-increasing in the buffer size.
        let tiny = d.ingest_seconds(1_000_000, 4, 1 << 10);
        let small = d.ingest_seconds(1_000_000, 4, 1 << 20);
        let big = d.ingest_seconds(1_000_000, 4, 64 << 20);
        assert!(tiny > small, "tiny={tiny} small={small}");
        assert!(small > big, "small={small} big={big}");
        // A buffer that holds everything degenerates to one run per
        // host: two streaming passes plus per-host seeks.
        let one_trip = d.read_seconds(4, 12_000_000, 0) * 2.0
            + d.read_seconds(4, 12_000_000, 1_000_000)
            + d.per_record_seconds * 1_000_000.0;
        assert!((big - one_trip).abs() < 1e-9, "big={big} one_trip={one_trip}");
        // Degenerate knobs stay finite and positive.
        assert!(d.ingest_seconds(1, 1, 0) > 0.0);
    }

    #[test]
    fn record_overhead_dominates_hub() {
        // The TR mega-hub: millions of edge records on one vertex.
        let d = DiskModel::default();
        let normal = d.read_seconds(1, 10_000_000, 100_000);
        let hub = d.read_seconds(1, 10_000_000, 50_000_000);
        assert!(hub > normal * 10.0, "hub={hub} normal={normal}");
    }
}
