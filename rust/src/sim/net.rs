//! Gigabit-Ethernet cost model (the paper's interconnect).

/// Latency + bandwidth network model for batched message transfer.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// One-way latency per batch/frame (seconds).
    pub latency_seconds: f64,
    /// Usable bandwidth (bytes/second).
    pub bytes_per_sec: f64,
    /// Per-message CPU cost (serialise + route + deliver).
    pub per_message_seconds: f64,
}

impl Default for NetModel {
    /// GbE on commodity switches: ~100 µs effective latency, ~117 MB/s
    /// usable, ~0.2 µs per message of CPU.
    fn default() -> Self {
        Self {
            latency_seconds: 100e-6,
            bytes_per_sec: 117e6,
            per_message_seconds: 2e-7,
        }
    }
}

impl NetModel {
    /// Time for one host to ship `bytes` in `batches` frames carrying
    /// `messages` messages.
    pub fn transfer_seconds(&self, batches: u64, bytes: u64, messages: u64) -> f64 {
        self.latency_seconds * batches as f64
            + bytes as f64 / self.bytes_per_sec
            + self.per_message_seconds * messages as f64
    }

    /// Barrier synchronisation cost for `k` workers + manager (gather
    /// syncs, scatter resumes — two sequentialised rounds of control
    /// messages, paper §4.2).
    pub fn barrier_seconds(&self, k: usize) -> f64 {
        2.0 * self.latency_seconds * (k as f64).max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_term() {
        let n = NetModel::default();
        let t = n.transfer_seconds(1, 117_000_000, 0);
        assert!((t - 1.0001).abs() < 1e-3, "{t}");
    }

    #[test]
    fn message_cpu_matters_for_chatty_workloads() {
        let n = NetModel::default();
        // Same bytes, 10M tiny messages vs 100 big ones.
        let chatty = n.transfer_seconds(1, 80_000_000, 10_000_000);
        let batched = n.transfer_seconds(1, 80_000_000, 100);
        assert!(chatty > batched * 2.0);
    }

    #[test]
    fn barrier_scales_with_workers() {
        let n = NetModel::default();
        assert!(n.barrier_seconds(12) > n.barrier_seconds(2));
    }
}
