//! Commodity-cluster cost models (the paper's testbed, simulated).
//!
//! The paper evaluates on 12 nodes (8-core Xeon, 16 GB RAM, 1 TB SATA
//! HDD, Gigabit Ethernet). Our engines run the *same algorithms with the
//! same message/superstep/byte counts* in-process; this module converts
//! those exact counts into cluster-shaped times so the benchmark
//! harnesses can present Fig 4a/4b-style results (DESIGN.md §3 documents
//! the substitution). Raw measured in-process times are always reported
//! alongside.

pub mod disk;
pub mod net;
pub mod cluster;

pub use cluster::{simulate_job, ClusterSpec, SimBreakdown};
pub use disk::DiskModel;
pub use net::NetModel;
