//! Whole-cluster simulation: combine measured engine counts with the
//! disk/net models to produce testbed-shaped times.

use crate::metrics::JobMetrics;

use super::disk::DiskModel;
use super::net::NetModel;

/// The simulated testbed (defaults = the paper's 12-node cluster).
#[derive(Clone, Copy, Debug)]
pub struct ClusterSpec {
    pub hosts: usize,
    pub cores_per_host: usize,
    pub disk: DiskModel,
    pub net: NetModel,
    /// Slowdown of one 2026 laptop core vs one 2013 Xeon core for this
    /// kind of pointer-chasing graph work (used to scale measured compute
    /// into testbed-shaped seconds; 1.0 = report measured as-is).
    pub cpu_scale: f64,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self {
            hosts: 12,
            cores_per_host: 8,
            disk: DiskModel::default(),
            net: NetModel::default(),
            cpu_scale: 1.0,
        }
    }
}

/// Simulated makespan breakdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimBreakdown {
    pub load_seconds: f64,
    pub compute_seconds: f64,
    pub comm_seconds: f64,
    pub sync_seconds: f64,
}

impl SimBreakdown {
    pub fn makespan(&self) -> f64 {
        self.load_seconds + self.compute_seconds + self.comm_seconds + self.sync_seconds
    }
}

/// Convert measured job metrics + a modelled load time into a simulated
/// cluster makespan:
///
/// * compute — per superstep, the *slowest* worker's measured compute
///   (BSP: the barrier waits for the straggler), CPU-scaled;
/// * comm    — per superstep, the cluster-wide bytes/messages through
///   the net model (divided across hosts; all-to-all overlaps);
/// * sync    — one barrier per superstep.
pub fn simulate_job(spec: &ClusterSpec, metrics: &JobMetrics, load_seconds: f64) -> SimBreakdown {
    let mut out = SimBreakdown { load_seconds, ..Default::default() };
    for ss in &metrics.supersteps {
        let slowest = ss
            .partition_compute_seconds
            .iter()
            .cloned()
            .fold(0.0, f64::max);
        out.compute_seconds += slowest * spec.cpu_scale;
        // Each host ships roughly bytes/hosts; batches ≈ one per peer.
        let hosts = spec.hosts.max(1) as u64;
        let per_host_bytes = ss.bytes / hosts;
        let per_host_msgs = ss.messages / hosts;
        let batches = (spec.hosts.saturating_sub(1)) as u64;
        out.comm_seconds +=
            spec.net
                .transfer_seconds(batches.max(1), per_host_bytes, per_host_msgs);
        out.sync_seconds += spec.net.barrier_seconds(spec.hosts);
    }
    out
}

/// Modelled GoFS load: every host reads its own slice files in parallel;
/// the slowest host gates the job (paper §6.3: "maximizes cumulative
/// disk read bandwidth across machines").
pub fn gofs_load_seconds(
    spec: &ClusterSpec,
    per_host: &[(u64, u64, u64)], // (files, bytes, records) per host
) -> f64 {
    per_host
        .iter()
        .map(|&(files, bytes, records)| spec.disk.read_seconds(files, bytes, records))
        .fold(0.0, f64::max)
}

/// Modelled HDFS/Giraph load: vertex data is block-placed without graph
/// locality, so a worker streams ~(hosts-1)/hosts of its bytes over the
/// network on top of disk, and materialises per-edge records. The host
/// that owns the highest-degree vertex pays its full record cost — the
/// paper's TR pathology (one O(millions)-degree vertex took "punitively
/// long to load into memory objects", §6.3).
pub fn hdfs_load_seconds(
    spec: &ClusterSpec,
    total_bytes: u64,
    total_records: u64,
    max_vertex_records: u64,
) -> f64 {
    // Giraph materialises Java objects per vertex/edge record. Calibrated
    // from the paper's own TR numbers: 798 s to load ~42 M records
    // ≈ 19 µs/record, i.e. ~100x GoFS's compact Kryo-style decode
    // (per_record_seconds = 0.2 µs).
    const GIRAPH_RECORD_FACTOR: f64 = 100.0;
    let hosts = spec.hosts.max(1) as u64;
    let per_host_bytes = total_bytes / hosts;
    let per_host_records = total_records / hosts;
    let remote_fraction = (hosts - 1) as f64 / hosts as f64;
    let disk = spec.disk.read_seconds(
        (per_host_bytes / (64 << 20)).max(1), // 64 MB HDFS blocks
        per_host_bytes,
        0,
    ) + spec.disk.per_record_seconds
        * GIRAPH_RECORD_FACTOR
        * per_host_records.max(max_vertex_records) as f64;
    let net = spec.net.transfer_seconds(
        1,
        (per_host_bytes as f64 * remote_fraction) as u64,
        0,
    );
    disk + net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SuperstepMetrics;

    fn metrics_with(walls: &[(f64, u64, u64)]) -> JobMetrics {
        let mut m = JobMetrics::default();
        for &(w, msgs, bytes) in walls {
            m.supersteps.push(SuperstepMetrics {
                wall_seconds: w,
                partition_compute_seconds: vec![w, w / 2.0],
                unit_times: vec![vec![w], vec![w / 2.0]],
                messages: msgs,
                bytes,
                active_units: 2,
                combined_messages: 0,
            });
            m.compute_seconds += w;
        }
        m
    }

    #[test]
    fn breakdown_accumulates_per_superstep() {
        let spec = ClusterSpec::default();
        let m = metrics_with(&[(0.1, 1000, 1 << 20), (0.2, 0, 0)]);
        let sim = simulate_job(&spec, &m, 3.0);
        assert_eq!(sim.load_seconds, 3.0);
        assert!((sim.compute_seconds - 0.3).abs() < 1e-9);
        assert!(sim.comm_seconds > 0.0);
        assert!(sim.sync_seconds > 0.0);
        assert!(sim.makespan() > 3.3);
    }

    #[test]
    fn more_supersteps_cost_more_sync() {
        let spec = ClusterSpec::default();
        let few = simulate_job(&spec, &metrics_with(&[(0.0, 0, 0); 5]), 0.0);
        let many = simulate_job(&spec, &metrics_with(&[(0.0, 0, 0); 500]), 0.0);
        assert!(many.sync_seconds > few.sync_seconds * 50.0);
    }

    #[test]
    fn gofs_load_is_slowest_host() {
        let spec = ClusterSpec::default();
        let t = gofs_load_seconds(
            &spec,
            &[(10, 1 << 20, 1000), (100, 200 << 20, 100_000), (1, 1 << 10, 10)],
        );
        let direct = spec.disk.read_seconds(100, 200 << 20, 100_000);
        assert!((t - direct).abs() < 1e-12);
    }

    #[test]
    fn hdfs_hub_vertex_dominates() {
        let spec = ClusterSpec::default();
        let normal = hdfs_load_seconds(&spec, 1 << 30, 20_000_000, 200_000);
        let hubbed = hdfs_load_seconds(&spec, 1 << 30, 20_000_000, 20_000_000);
        assert!(hubbed > normal * 2.0, "hubbed={hubbed} normal={normal}");
    }
}
