//! In-crate micro/macro benchmark harness (criterion is not in the
//! offline vendor set; DESIGN.md §3).
//!
//! `cargo bench` runs the `rust/benches/*.rs` binaries (harness = false),
//! each of which uses [`measure`] / [`Table`] to print the paper's
//! tables and figures as text.

use std::time::Instant;

use crate::util::stats;

/// Timing result of a benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub times: Vec<f64>,
    pub median: f64,
    pub min: f64,
}

/// Run `f` `warmup + reps` times; report stats over the last `reps`.
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let median = stats::median(&times);
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    Measurement { times, median, min }
}

/// Fixed-width text table writer for bench output (the "figure" format).
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths; also returns the string.
    pub fn print(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        print!("{out}");
        out
    }
}

/// Format seconds in engineering units.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Format a speedup factor like the paper ("81x", "1.4x", "0.4x").
pub fn fmt_speedup(x: f64) -> String {
    if x >= 10.0 {
        format!("{x:.0}x")
    } else {
        format!("{x:.1}x")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_reps() {
        let mut calls = 0;
        let m = measure(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(m.times.len(), 5);
        assert!(m.min <= m.median);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(&["x".into(), "1".into()]);
        t.row(&["yyyy".into(), "2".into()]);
        let s = t.print();
        assert!(s.contains("=== demo ==="));
        assert!(s.contains("long_header"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(0.0000025), "2.5us");
        assert_eq!(fmt_speedup(81.4), "81x");
        assert_eq!(fmt_speedup(1.42), "1.4x");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only one".into()]);
    }
}
