//! In-crate micro/macro benchmark harness (criterion is not in the
//! offline vendor set; DESIGN.md §3).
//!
//! `cargo bench` runs the `rust/benches/*.rs` binaries (harness = false),
//! each of which uses [`measure`] / [`Table`] to print the paper's
//! tables and figures as text — and, when `GOFFISH_BENCH_JSON` names a
//! file, appends machine-readable result rows through [`JsonEmitter`]
//! so CI can record the perf trajectory (`BENCH_PR*.json` artifacts)
//! instead of scrolling text tables.

use std::path::PathBuf;
use std::time::Instant;

use crate::util::stats;

/// Timing result of a benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub times: Vec<f64>,
    pub median: f64,
    pub min: f64,
}

/// Run `f` `warmup + reps` times; report stats over the last `reps`.
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let median = stats::median(&times);
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    Measurement { times, median, min }
}

/// Fixed-width text table writer for bench output (the "figure" format).
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column widths; also returns the string.
    pub fn print(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n=== {} ===\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        print!("{out}");
        out
    }
}

/// Machine-readable benchmark rows, one JSON object per line:
/// `{"bench": …, "dataset": …, "metric": …, "value": …, "scale": …}`.
///
/// The env var `GOFFISH_BENCH_JSON` names the append-target file; CI
/// collects the lines from every bench binary into one JSON array
/// (`jq -s`) and uploads it as the `BENCH_PR*.json` trend artifact.
/// Without the env var the emitter is a no-op, so local `cargo bench`
/// output is unchanged.
pub struct JsonEmitter {
    bench: String,
    scale: f64,
    path: Option<PathBuf>,
    rows: Vec<String>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render an f64 as a JSON number (`null` for non-finite values, which
/// JSON cannot carry).
fn json_number(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

impl JsonEmitter {
    /// Emitter writing to `path` (or collecting rows invisibly if
    /// `None`).
    pub fn new(path: Option<PathBuf>, bench: &str, scale: f64) -> Self {
        Self { bench: bench.to_string(), scale, path, rows: Vec::new() }
    }

    /// Emitter targeting the `GOFFISH_BENCH_JSON` file, if set.
    pub fn from_env(bench: &str, scale: f64) -> Self {
        Self::new(std::env::var_os("GOFFISH_BENCH_JSON").map(PathBuf::from), bench, scale)
    }

    /// Record one datum of the current bench run.
    pub fn emit(&mut self, dataset: &str, metric: &str, value: f64) {
        self.rows.push(format!(
            "{{\"bench\":\"{}\",\"dataset\":\"{}\",\"metric\":\"{}\",\"value\":{},\"scale\":{}}}",
            json_escape(&self.bench),
            json_escape(dataset),
            json_escape(metric),
            json_number(value),
            json_number(self.scale),
        ));
    }

    /// Rows collected so far (test/inspection surface).
    pub fn rows(&self) -> &[String] {
        &self.rows
    }

    /// Append all collected rows to the target file. IO failure is
    /// reported on stderr but never fails the bench itself.
    pub fn finish(self) {
        let Some(path) = self.path else { return };
        let write = || -> std::io::Result<()> {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)?;
            for row in &self.rows {
                writeln!(f, "{row}")?;
            }
            f.flush()
        };
        if let Err(e) = write() {
            eprintln!("bench: failed to append JSON rows to {}: {e}", path.display());
        }
    }
}

/// Format seconds in engineering units.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Format a speedup factor like the paper ("81x", "1.4x", "0.4x").
pub fn fmt_speedup(x: f64) -> String {
    if x >= 10.0 {
        format!("{x:.0}x")
    } else {
        format!("{x:.1}x")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_reps() {
        let mut calls = 0;
        let m = measure(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(m.times.len(), 5);
        assert!(m.min <= m.median);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(&["x".into(), "1".into()]);
        t.row(&["yyyy".into(), "2".into()]);
        let s = t.print();
        assert!(s.contains("=== demo ==="));
        assert!(s.contains("long_header"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(0.0000025), "2.5us");
        assert_eq!(fmt_speedup(81.4), "81x");
        assert_eq!(fmt_speedup(1.42), "1.4x");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only one".into()]);
    }

    #[test]
    fn json_rows_schema_and_append() {
        let path = std::env::temp_dir()
            .join(format!("goffish_bench_json_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);

        let mut e = JsonEmitter::new(Some(path.clone()), "fig4b_loading", 0.05);
        e.emit("RN", "v2_parallel_seconds", 0.125);
        e.emit("TR", "full_load_bytes", 4096.0);
        assert_eq!(e.rows().len(), 2);
        assert_eq!(
            e.rows()[0],
            "{\"bench\":\"fig4b_loading\",\"dataset\":\"RN\",\
             \"metric\":\"v2_parallel_seconds\",\"value\":0.125,\"scale\":0.05}"
        );
        e.finish();

        // A second emitter appends (several bench binaries, one file).
        let mut e2 = JsonEmitter::new(Some(path.clone()), "micro", 0.05);
        e2.emit("-", "codec_rt_seconds", 0.5);
        e2.finish();

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            assert!(line.starts_with("{\"bench\":\"") && line.ends_with('}'), "{line}");
            for key in ["\"bench\":", "\"dataset\":", "\"metric\":", "\"value\":", "\"scale\":"] {
                assert!(line.contains(key), "{line} missing {key}");
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn json_escaping_and_nonfinite_values() {
        let mut e = JsonEmitter::new(None, "weird\"bench\\", 0.1);
        e.emit("d\n", "m", f64::NAN);
        assert_eq!(
            e.rows()[0],
            "{\"bench\":\"weird\\\"bench\\\\\",\"dataset\":\"d\\u000a\",\
             \"metric\":\"m\",\"value\":null,\"scale\":0.1}"
        );
        e.finish(); // no path: a no-op
    }
}
