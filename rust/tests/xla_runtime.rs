//! XLA runtime integration: the AOT artifacts (built by `make artifacts`)
//! load, compile and execute via PJRT, and their numerics match the Rust
//! scalar implementations — the cross-language correctness seal between
//! L1/L2 (Python) and L3 (Rust).
//!
//! Gated: when the artifacts are absent (or the PJRT backend is the
//! offline stub) every test here skips instead of failing, so tier-1
//! stays green on machines that never ran `make artifacts`.

use std::path::Path;
use std::sync::Arc;

use goffish::algos::gather_vertex_values;
use goffish::algos::pagerank::{PageRankSg, RankKernel};
use goffish::gofs::subgraph::discover;
use goffish::gopher::{run, GopherConfig};
use goffish::graph::gen;
use goffish::partition::{MultilevelPartitioner, Partitioner};
use goffish::runtime::XlaEngine;

fn engine() -> Option<Arc<XlaEngine>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping xla test: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    match XlaEngine::load(&dir) {
        Ok(e) => Some(Arc::new(e)),
        Err(e) => {
            eprintln!("skipping xla test: engine unavailable: {e:#}");
            None
        }
    }
}

#[test]
fn ladder_metadata() {
    let Some(e) = engine() else { return };
    assert_eq!(e.max_rung(), 512);
    assert_eq!(e.rung_for(1), Some(64));
    assert_eq!(e.rung_for(64), Some(64));
    assert_eq!(e.rung_for(65), Some(128));
    assert_eq!(e.rung_for(513), None);
    assert!(e.loops("sssp_relax") >= 1);
}

#[test]
fn pagerank_step_matches_scalar() {
    let Some(e) = engine() else { return };
    let n_pad = 64usize;
    let n = 10; // live vertices, rest padding
    // Ring 0->1->...->9->0 in in-link orientation A[(i+1)%n][i] = 1.
    let mut adj = vec![0f32; n_pad * n_pad];
    for i in 0..n {
        adj[((i + 1) % n) * n_pad + i] = 1.0;
    }
    let mut ranks = vec![0f32; n_pad];
    let mut out_deg = vec![-1f32; n_pad];
    for i in 0..n {
        ranks[i] = 1.0 / n as f32;
        out_deg[i] = 1.0;
    }
    let base = 0.15 / n as f32;
    let got = e.pagerank_step(n_pad, &adj, &ranks, &out_deg, base, 0.85).unwrap();
    // Scalar expectation: uniform stays uniform on a ring.
    for i in 0..n {
        assert!((got[i] - 1.0 / n as f32).abs() < 1e-6, "i={i} got={}", got[i]);
    }
    for i in n..n_pad {
        assert_eq!(got[i], 0.0, "padding row {i} leaked rank");
    }
}

#[test]
fn sssp_relax_reaches_chain() {
    let Some(e) = engine() else { return };
    let n_pad = 64usize;
    let n = 9;
    let inf = f32::INFINITY;
    let mut w = vec![inf; n_pad * n_pad];
    for i in 0..n - 1 {
        w[(i + 1) * n_pad + i] = 2.0; // edge i -> i+1, weight 2
    }
    let mut dist = vec![inf; n_pad];
    dist[0] = 0.0;
    let sweeps = e.loops("sssp_relax");
    let mut d = dist;
    // Each call performs `sweeps` sweeps; chain needs n-1 total.
    let calls = (n - 1).div_ceil(sweeps);
    for _ in 0..calls {
        d = e.sssp_relax(n_pad, &w, &d).unwrap();
    }
    for (i, item) in d.iter().enumerate().take(n) {
        assert!((item - 2.0 * i as f32).abs() < 1e-6, "i={i} d={item}");
    }
    assert!(d[n..].iter().all(|x| x.is_infinite()));
}

#[test]
fn cc_flood_labels_components() {
    let Some(e) = engine() else { return };
    let n_pad = 64usize;
    // Two components: {0,1,2} and {3,4}; symmetric adjacency.
    let mut adj = vec![0f32; n_pad * n_pad];
    for (a, b) in [(0usize, 1usize), (1, 2), (3, 4)] {
        adj[a * n_pad + b] = 1.0;
        adj[b * n_pad + a] = 1.0;
    }
    let mut labels = vec![f32::NEG_INFINITY; n_pad];
    for (i, l) in labels.iter_mut().enumerate().take(5) {
        *l = i as f32;
    }
    let out = e.cc_flood(n_pad, &adj, &labels).unwrap();
    assert_eq!(&out[..5], &[2.0, 2.0, 2.0, 4.0, 4.0]);
}

#[test]
fn pagerank_local_distribution() {
    let Some(e) = engine() else { return };
    let n_pad = 64usize;
    let n = 8;
    // Star: everyone points at vertex 0 (in-link row 0 full).
    let mut adj = vec![0f32; n_pad * n_pad];
    for j in 1..n {
        adj[j] = 1.0; // A[0][j]
    }
    let mut out_deg = vec![-1f32; n_pad];
    out_deg[0] = 0.0; // dangling hub
    for d in out_deg.iter_mut().take(n).skip(1) {
        *d = 1.0;
    }
    let base = 0.15 / n as f32;
    let got = e.pagerank_local(n_pad, &adj, &out_deg, base, 0.85).unwrap();
    // Hub must dominate the spokes.
    assert!(got[0] > 3.0 * got[1], "hub={} spoke={}", got[0], got[1]);
    assert!(got[n..].iter().all(|&x| x == 0.0));
}

#[test]
fn gopher_pagerank_xla_matches_scalar_end_to_end() {
    // The headline integration: a full Gopher job whose per-sub-graph
    // inner loop runs through the Pallas-derived XLA kernel must produce
    // the same ranks as the scalar path.
    let Some(e) = engine() else { return };
    let g = gen::social(600, 4, 0.02, 77);
    let parts = MultilevelPartitioner::default().partition(&g, 3);
    let dg = discover(&g, &parts).unwrap();
    // Sub-graphs beyond the ladder fall back to scalar, which must
    // *still* agree — both paths are exercised by this graph.
    let scalar = {
        let prog = PageRankSg { supersteps: 10, kernel: RankKernel::Scalar, epsilon: None };
        let res = run(&dg, &prog, &GopherConfig::default()).unwrap();
        let states: std::collections::BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.ranks)).collect();
        gather_vertex_values(&dg, &states)
    };
    let xla = {
        let prog = PageRankSg { supersteps: 10, kernel: RankKernel::Xla(e), epsilon: None };
        let res = run(&dg, &prog, &GopherConfig::default()).unwrap();
        let states: std::collections::BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.ranks)).collect();
        gather_vertex_values(&dg, &states)
    };
    for (v, (&a, &b)) in scalar.iter().zip(&xla).enumerate() {
        assert!(
            (a - b).abs() < 1e-6 + 1e-4 * b.abs(),
            "vertex {v}: scalar={a} xla={b}"
        );
    }
}

#[test]
fn shape_errors_rejected() {
    let Some(e) = engine() else { return };
    assert!(e.pagerank_step(63, &[0.0; 63 * 63], &[0.0; 63], &[0.0; 63], 0.1, 0.85).is_err());
    assert!(e.sssp_relax(64, &[0.0; 64], &[0.0; 64]).is_err());
}
