//! Property tests over coordinator invariants (routing, discovery,
//! partitioning, codec) and the GoFS storage formats, using the
//! in-crate `testing::prop` harness and the shared
//! `testing::fixtures` graph builders.

use goffish::algos::cc::CcSg;
use goffish::algos::gather_subgraph_values;
use goffish::gofs::subgraph::discover;
use goffish::gofs::{AttrProjection, DistributedGraph, LoadOptions, SliceFormat, Store};
use goffish::gopher::{run, GopherConfig};
use goffish::graph::{gen, io, props, Graph};
use goffish::ingest::{ingest_edge_list, IngestOptions};
use goffish::partition::{
    HashPartitioner, MultilevelPartitioner, Partitioner, Partitioning,
};
use goffish::testing::fixtures;
use goffish::testing::{prop, prop_with_rng};
use goffish::util::codec::{Decoder, Encoder};
use goffish::util::rng::Rng;

fn arbitrary_graph(rng: &mut Rng) -> Graph {
    fixtures::small_graph(rng)
}

fn arbitrary_partitioning(rng: &mut Rng, g: &Graph) -> Partitioning {
    fixtures::random_partitioning(rng, g)
}

#[test]
fn prop_partitioners_cover_each_vertex_once() {
    prop(
        "partition covers vertices exactly once",
        40,
        |rng| {
            let g = arbitrary_graph(rng);
            let p = arbitrary_partitioning(rng, &g);
            (g.num_vertices(), p)
        },
        |(n, p)| {
            if p.num_vertices() != *n {
                return Err(format!("covers {} of {n}", p.num_vertices()));
            }
            if p.sizes().iter().sum::<usize>() != *n {
                return Err("sizes don't sum to n".into());
            }
            if p.assignment().iter().any(|&a| a as usize >= p.k()) {
                return Err("assignment out of range".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_subgraph_discovery_is_partition_refinement() {
    prop(
        "sub-graphs refine partitions and preserve edges",
        30,
        |rng| {
            let g = arbitrary_graph(rng);
            let p = arbitrary_partitioning(rng, &g);
            let dg = discover(&g, &p).unwrap();
            (g, p, dg)
        },
        |(g, p, dg)| {
            // Each sub-graph's vertices all belong to its partition.
            for sg in dg.subgraphs() {
                for &v in &sg.vertices {
                    if p.of(v) != sg.id.partition {
                        return Err(format!("vertex {v} outside partition"));
                    }
                }
            }
            // Edge conservation.
            let local: usize = dg.subgraphs().map(|s| s.local.num_edges()).sum();
            let remote: usize = dg.subgraphs().map(|s| s.remote_out.len()).sum();
            if local + remote != g.num_edges() {
                return Err(format!(
                    "edges {} != local {local} + remote {remote}",
                    g.num_edges()
                ));
            }
            // Remote edges really cross partitions.
            for sg in dg.subgraphs() {
                for r in &sg.remote_out {
                    if r.partition == sg.id.partition {
                        return Err("remote edge within partition".into());
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cc_equals_ground_truth_wcc() {
    prop(
        "engine CC == union-find WCC",
        15,
        |rng| {
            let g = arbitrary_graph(rng);
            let p = arbitrary_partitioning(rng, &g);
            (g, p)
        },
        |(g, p)| {
            let dg = discover(g, p).map_err(|e| e.to_string())?;
            let res =
                run(&dg, &CcSg, &GopherConfig::default()).map_err(|e| e.to_string())?;
            let labels = gather_subgraph_values(&dg, &res.states);
            let truth = props::wcc_labels(g);
            // Labels must induce exactly the same partition as truth.
            for (u, v, _) in g.edges() {
                if labels[u as usize] != labels[v as usize] {
                    return Err(format!("edge ({u},{v}) split by labels"));
                }
            }
            let distinct =
                |xs: &[u32]| xs.iter().collect::<std::collections::HashSet<_>>().len();
            if distinct(&labels) != distinct(&truth) {
                return Err(format!(
                    "{} components vs truth {}",
                    distinct(&labels),
                    distinct(&truth)
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_codec_round_trips_arbitrary_sequences() {
    #[derive(Debug)]
    struct Case {
        ops: Vec<(u8, u64)>,
    }
    prop(
        "codec round-trip",
        200,
        |rng| {
            let n = rng.index(40);
            Case {
                ops: (0..n).map(|_| (rng.index(4) as u8, rng.next_u64())).collect(),
            }
        },
        |case| {
            let mut e = Encoder::new();
            for &(kind, v) in &case.ops {
                match kind {
                    0 => e.put_varint(v),
                    1 => e.put_signed(v as i64),
                    2 => e.put_f64(f64::from_bits(v | 1)), // avoid NaN compares
                    _ => e.put_str(&format!("{v:x}")),
                }
            }
            let bytes = e.into_bytes();
            let mut d = Decoder::new(&bytes);
            for &(kind, v) in &case.ops {
                match kind {
                    0 => {
                        if d.get_varint().map_err(|e| e.to_string())? != v {
                            return Err("varint mismatch".into());
                        }
                    }
                    1 => {
                        if d.get_signed().map_err(|e| e.to_string())? != v as i64 {
                            return Err("signed mismatch".into());
                        }
                    }
                    2 => {
                        let got = d.get_f64().map_err(|e| e.to_string())?;
                        let want = f64::from_bits(v | 1);
                        if got.to_bits() != want.to_bits() && !(got.is_nan() && want.is_nan()) {
                            return Err("f64 mismatch".into());
                        }
                    }
                    _ => {
                        if d.get_str().map_err(|e| e.to_string())? != format!("{v:x}") {
                            return Err("str mismatch".into());
                        }
                    }
                }
            }
            if !d.is_at_end() {
                return Err("trailing bytes".into());
            }
            Ok(())
        },
    );
}

/// Everything the store hands back that the engines consume, in a
/// comparable shape: per-sub-graph vertices, weighted edge lists, and
/// remote-ref counts.
fn observable_shape(d: &DistributedGraph) -> Vec<(Vec<u32>, Vec<(u32, u32, f32)>, usize, usize)> {
    d.subgraphs()
        .map(|s| {
            let edges: Vec<(u32, u32, f32)> = s
                .local
                .edges()
                .map(|(u, v, ei)| (u, v, s.local.weight(ei)))
                .collect();
            (s.vertices.clone(), edges, s.remote_out.len(), s.remote_in.len())
        })
        .collect()
}

#[test]
fn prop_store_formats_load_identically_under_any_projection() {
    // The paper's storage contract, as a property: the same graph +
    // partitioning written as v1 slices, v2 columnar slices, or a v3
    // packed store must load back *identical* sub-graphs and attribute
    // columns, for a random `AttrProjection`, both sequentially and on
    // the `util::pool` parallel path, and (for v3, where the knob has
    // effect) through both the mmap and the seek+read decode paths.
    // Twelve observations per case (3 formats × 2 modes × 2 byte
    // paths) must agree exactly.
    prop_with_rng(
        "v1/v2/v3 × seq/par × mmap/read loads agree",
        8,
        |rng| {
            let base = fixtures::random_graph(rng);
            let g = fixtures::maybe_weighted(rng, base);
            let p = fixtures::random_partitioning(rng, &g);
            let n_attrs = rng.index(4);
            (g, p, n_attrs)
        },
        |(g, p, n_attrs), rng| {
            let projection = match (*n_attrs, rng.index(3)) {
                (0, _) | (_, 0) => {
                    if rng.chance(0.5) {
                        AttrProjection::None
                    } else {
                        AttrProjection::All
                    }
                }
                (_, 1) => AttrProjection::All,
                _ => {
                    let keep: Vec<String> = (0..*n_attrs)
                        .filter(|_| rng.chance(0.5))
                        .map(|a| format!("attr{a}"))
                        .collect();
                    if keep.is_empty() {
                        AttrProjection::None
                    } else {
                        AttrProjection::Only(keep)
                    }
                }
            };
            let tag = rng.next_u64();
            let mut observations = Vec::new();
            for fmt in [SliceFormat::V1, SliceFormat::V2, SliceFormat::V3Packed] {
                let root = std::env::temp_dir()
                    .join("goffish_prop_formats")
                    .join(format!("{tag:016x}_{fmt}_{}", std::process::id()));
                let _ = std::fs::remove_dir_all(&root);
                let (store, dg) = Store::create_with_format(&root, "g", g, p, fmt)
                    .map_err(|e| format!("create {fmt}: {e:#}"))?;
                let mut items = Vec::new();
                for sg in dg.subgraphs() {
                    for a in 0..*n_attrs {
                        let vals: Vec<f32> = sg
                            .vertices
                            .iter()
                            .map(|&v| v as f32 * 0.5 + a as f32)
                            .collect();
                        items.push((sg.id, format!("attr{a}"), vals));
                    }
                }
                store
                    .write_attributes(&items)
                    .map_err(|e| format!("attrs {fmt}: {e:#}"))?;
                for sequential in [true, false] {
                    for mmap in [true, false] {
                        let opts = LoadOptions {
                            attributes: projection.clone(),
                            sequential,
                            cores: 0,
                            mmap,
                        };
                        let (dg2, attrs, stats) = store
                            .load_all_with(&opts)
                            .map_err(|e| {
                                format!("load {fmt} seq={sequential} mmap={mmap}: {e:#}")
                            })?;
                        if stats.bytes == 0 {
                            return Err(format!("{fmt}: load reported zero bytes"));
                        }
                        observations.push((
                            format!("{fmt} mmap={mmap}"),
                            sequential,
                            observable_shape(&dg2),
                            attrs,
                        ));
                    }
                }
                let _ = std::fs::remove_dir_all(&root);
            }
            let (_, _, shape0, attrs0) = &observations[0];
            for (fmt, sequential, shape, attrs) in &observations[1..] {
                if shape != shape0 {
                    return Err(format!("{fmt} seq={sequential}: sub-graphs diverge"));
                }
                if attrs != attrs0 {
                    return Err(format!("{fmt} seq={sequential}: attribute columns diverge"));
                }
            }
            Ok(())
        },
    );
}

/// Sorted `(file name, bytes)` listing of one directory.
fn dir_bytes(dir: &std::path::Path) -> Result<Vec<(String, Vec<u8>)>, String> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))? {
        let entry = entry.map_err(|e| e.to_string())?;
        if entry.path().is_file() {
            out.push((
                entry.file_name().to_string_lossy().into_owned(),
                std::fs::read(entry.path()).map_err(|e| e.to_string())?,
            ));
        }
    }
    out.sort();
    Ok(out)
}

#[test]
fn prop_streamed_store_equals_batch_store() {
    // The ingest contract as a property: streaming a random edge list
    // through `crate::ingest` with a spill buffer far smaller than the
    // input must produce a store *byte-identical* to the batch path
    // (read whole graph → hash partition → Store::create) — same file
    // set, same bytes, before and after attribute writes — and load
    // back identically under a random AttrProjection.
    prop_with_rng(
        "streamed store == batch store (byte-level)",
        8,
        |rng| {
            let base = fixtures::random_graph(rng);
            let g = fixtures::maybe_weighted(rng, base);
            let hosts = 1 + rng.index(3) as u32;
            let spill_buffer = 1 + rng.index(64); // bytes: spills constantly
            let seed = rng.next_u64();
            let n_attrs = rng.index(3);
            (g, hosts, spill_buffer, seed, n_attrs)
        },
        |(g, hosts, spill_buffer, seed, n_attrs), rng| {
            if g.num_edges() == 0 {
                return Ok(()); // an edge-list file cannot carry isolated vertices
            }
            let fmt = match rng.index(3) {
                0 => SliceFormat::V1,
                1 => SliceFormat::V2,
                _ => SliceFormat::V3Packed,
            };
            let tag = rng.next_u64();
            let base = std::env::temp_dir()
                .join("goffish_prop_ingest")
                .join(format!("{tag:016x}_{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&base);
            std::fs::create_dir_all(&base).map_err(|e| e.to_string())?;
            let list = base.join("edges.tsv");
            io::write_edge_list(g, &list).map_err(|e| format!("write list: {e:#}"))?;

            // Batch path: re-read the list (the file round-trip is the
            // shared ground truth), hash-partition, create.
            let g2 = io::read_edge_list(&list, g.directed())
                .map_err(|e| format!("re-read: {e:#}"))?;
            let p = HashPartitioner::new(*seed).partition(&g2, *hosts as usize);
            let (batch_store, dg) =
                Store::create_with_format(&base.join("batch"), "graph", &g2, &p, fmt)
                    .map_err(|e| format!("batch create: {e:#}"))?;

            // Streamed path: same list, same knobs, tiny spill buffer.
            let opts = IngestOptions {
                name: "graph".to_string(),
                hosts: *hosts,
                format: fmt,
                directed: g.directed(),
                spill_buffer: *spill_buffer,
                seed: *seed,
            };
            let (streamed_store, report) =
                ingest_edge_list(&list, &base.join("streamed"), &opts)
                    .map_err(|e| format!("ingest: {e:#}"))?;
            if report.edges != g2.num_edges() as u64 {
                return Err(format!(
                    "report counts {} edges, list has {}",
                    report.edges,
                    g2.num_edges()
                ));
            }

            // Byte-identical partition files + meta, then again after
            // writing the same attribute columns to both stores.
            let mut attr_items = Vec::new();
            for sg in dg.subgraphs() {
                for a in 0..*n_attrs {
                    let vals: Vec<f32> =
                        sg.vertices.iter().map(|&v| v as f32 + a as f32).collect();
                    attr_items.push((sg.id, format!("attr{a}"), vals));
                }
            }
            for (label, with_attrs) in [("topology", false), ("with attrs", true)] {
                if with_attrs {
                    batch_store
                        .write_attributes(&attr_items)
                        .map_err(|e| format!("batch attrs: {e:#}"))?;
                    streamed_store
                        .write_attributes(&attr_items)
                        .map_err(|e| format!("streamed attrs: {e:#}"))?;
                }
                for p in 0..*hosts {
                    let host = format!("host{p}");
                    let a = dir_bytes(&base.join("batch").join(&host))?;
                    let b = dir_bytes(&base.join("streamed").join(&host))?;
                    if a != b {
                        return Err(format!("{label}: {host} files diverge ({fmt})"));
                    }
                }
                let meta_a = std::fs::read(base.join("batch").join("meta.txt"))
                    .map_err(|e| e.to_string())?;
                let meta_b = std::fs::read(base.join("streamed").join("meta.txt"))
                    .map_err(|e| e.to_string())?;
                if meta_a != meta_b {
                    return Err(format!("{label}: meta.txt diverges"));
                }
            }

            // Loads agree under a random projection.
            let projection = match rng.index(3) {
                0 => AttrProjection::None,
                1 => AttrProjection::All,
                _ => AttrProjection::Only(vec!["attr0".to_string()]),
            };
            let projection = match (&projection, *n_attrs) {
                (AttrProjection::Only(_), 0) => AttrProjection::All,
                _ => projection,
            };
            let load = LoadOptions {
                attributes: projection,
                sequential: true,
                cores: 0,
                ..Default::default()
            };
            let (dg_a, attrs_a, _) = batch_store
                .load_all_with(&load)
                .map_err(|e| format!("batch load: {e:#}"))?;
            let (dg_b, attrs_b, _) = streamed_store
                .load_all_with(&load)
                .map_err(|e| format!("streamed load: {e:#}"))?;
            if observable_shape(&dg_a) != observable_shape(&dg_b) {
                return Err("loaded sub-graphs diverge".into());
            }
            if attrs_a != attrs_b {
                return Err("loaded attribute columns diverge".into());
            }
            let _ = std::fs::remove_dir_all(&base);
            Ok(())
        },
    );
}

#[test]
fn prop_meta_graph_diameter_bounds_cc_supersteps() {
    // The paper's superstep bound: traversal supersteps <= meta-diameter
    // + constant. Verify on random road graphs.
    prop(
        "CC supersteps bounded by meta-diameter + 2",
        8,
        |rng| {
            let g = gen::road(6 + rng.index(10), 0.85 + rng.f64() * 0.14, 0.02, rng.next_u64());
            let p = MultilevelPartitioner::new(rng.next_u64()).partition(&g, 2 + rng.index(3));
            (g, p)
        },
        |(g, p)| {
            let dg = discover(g, p).map_err(|e| e.to_string())?;
            let meta = dg.meta_graph();
            let d = props::diameter_exact(&meta) as usize;
            let res =
                run(&dg, &CcSg, &GopherConfig::default()).map_err(|e| e.to_string())?;
            let steps = res.metrics.num_supersteps();
            if steps > d + 2 {
                return Err(format!("steps={steps} meta-diameter={d}"));
            }
            Ok(())
        },
    );
}
