//! End-to-end tests of the `goffish serve` HTTP API over real sockets:
//! an ephemeral-port server per test, byte-level result parity with
//! direct job runs, stable paging, concurrent jobs, cancellation
//! latency, and bounded admission.

use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use goffish::gofs::{AppendBatch, SliceFormat, Store};
use goffish::graph::gen;
use goffish::job::{Job, JobSource};
use goffish::partition::{Partitioner, RangePartitioner};
use goffish::serve::json::JsonValue;
use goffish::serve::{ResidentGraph, ServeOptions, Server};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("goffish_serve_api")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Build a chain(n) store, load it resident, and start a server on an
/// ephemeral port. Returns the handle plus the store for solo runs.
fn serve_chain(name: &str, n: usize, k: usize, workers: usize, queue: usize) -> (Server, Store) {
    let g = gen::chain(n);
    let parts = RangePartitioner.partition(&g, k);
    let root = tmp(name);
    let (store, _) = Store::create(&root, name, &g, &parts).unwrap();
    let resident = ResidentGraph::open(&root).unwrap();
    let opts = ServeOptions { port: 0, workers, queue, cores: 2, keep_results: None };
    let server = Server::start(resident, &opts).unwrap();
    (server, store)
}

/// Minimal HTTP client: one request, read to EOF (the server closes
/// every connection), return (status, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let status: u16 = raw
        .split(' ')
        .nth(1)
        .and_then(|t| t.parse().ok())
        .unwrap_or_else(|| panic!("bad response {raw:?}"));
    let body = raw
        .split_once("\r\n\r\n")
        .map(|(_, b)| b.to_string())
        .unwrap_or_default();
    (status, body)
}

fn get_json(addr: SocketAddr, path: &str) -> (u16, JsonValue) {
    let (st, body) = http(addr, "GET", path, "");
    (st, JsonValue::parse(&body).unwrap_or_else(|e| panic!("bad JSON {body:?}: {e}")))
}

fn status_of(v: &JsonValue) -> String {
    v.get("status").unwrap().as_str().unwrap().to_string()
}

fn superstep_of(v: &JsonValue) -> usize {
    v.get("superstep").unwrap().as_f64().unwrap() as usize
}

/// Poll a job until its status satisfies `done`, with a hard deadline.
fn wait_until(addr: SocketAddr, id: u64, done: impl Fn(&JsonValue) -> bool) -> JsonValue {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let (st, v) = get_json(addr, &format!("/v1/jobs/{id}"));
        assert_eq!(st, 200);
        if done(&v) {
            return v;
        }
        assert!(
            Instant::now() < deadline,
            "job {id} stuck in {:?}",
            status_of(&v)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn wait_terminal(addr: SocketAddr, id: u64) -> JsonValue {
    wait_until(addr, id, |v| {
        matches!(status_of(v).as_str(), "done" | "failed" | "cancelled")
    })
}

/// Submit a job spec; expect 202 and return the assigned id.
fn submit(addr: SocketAddr, spec: &str) -> u64 {
    let (st, body) = http(addr, "POST", "/v1/jobs", spec);
    assert_eq!(st, 202, "submit {spec}: {body}");
    let v = JsonValue::parse(&body).unwrap();
    assert_eq!(status_of(&v), "queued");
    v.get("id").unwrap().as_f64().unwrap() as u64
}

/// The CLI `run --output` rendering of a value list.
fn tsv_of(values: &[(u32, f64)]) -> String {
    let mut s = String::new();
    for (v, x) in values {
        let _ = writeln!(s, "{v}\t{x}");
    }
    s
}

#[test]
fn submitted_job_matches_direct_run_with_stable_paging() {
    let (server, store) = serve_chain("parity", 100, 3, 1, 8);
    let addr = server.addr();

    let id = submit(addr, "{\"algo\":\"cc\",\"cores\":2}");
    let done = wait_terminal(addr, id);
    assert_eq!(status_of(&done), "done", "{done:?}");
    assert_eq!(done.get("num_values").unwrap().as_f64(), Some(100.0));

    // Solo run of the identical job description, straight off the store.
    let solo = Job::builder()
        .algo("cc")
        .cores(2)
        .build()
        .unwrap()
        .run(JobSource::Store(&store))
        .unwrap();
    let golden = tsv_of(&solo.values);

    // Full TSV page is byte-identical to the CLI-style rendering.
    let (st, full) = http(addr, "GET", &format!("/v1/jobs/{id}/results?limit=1000&format=tsv"), "");
    assert_eq!(st, 200);
    assert_eq!(full, golden);

    // Two disjoint pages concatenate to the full result, and a page
    // re-fetched is byte-identical (results are held, not recomputed).
    let (_, page1) = http(addr, "GET", &format!("/v1/jobs/{id}/results?offset=0&limit=30&format=tsv"), "");
    let (_, page2) = http(addr, "GET", &format!("/v1/jobs/{id}/results?offset=30&limit=1000&format=tsv"), "");
    assert_eq!(format!("{page1}{page2}"), golden);
    let (_, page1_again) = http(addr, "GET", &format!("/v1/jobs/{id}/results?offset=0&limit=30&format=tsv"), "");
    assert_eq!(page1, page1_again);

    // The JSON page carries the same values with paging metadata.
    let (st, v) = get_json(addr, &format!("/v1/jobs/{id}/results?offset=98&limit=10"));
    assert_eq!(st, 200);
    assert_eq!(v.get("total").unwrap().as_f64(), Some(100.0));
    assert_eq!(v.get("offset").unwrap().as_f64(), Some(98.0));
    assert_eq!(v.get("count").unwrap().as_f64(), Some(2.0));
    let vals = v.get("values").unwrap().as_array().unwrap();
    assert_eq!(vals.len(), 2);
    let last = vals[1].as_array().unwrap();
    assert_eq!(last[0].as_f64(), Some(f64::from(solo.values[99].0)));
    assert_eq!(last[1].as_f64(), Some(solo.values[99].1));

    // Out-of-range offsets page to empty rather than erroring.
    let (st, v) = get_json(addr, &format!("/v1/jobs/{id}/results?offset=500"));
    assert_eq!(st, 200);
    assert_eq!(v.get("count").unwrap().as_f64(), Some(0.0));

    server.shutdown();
}

#[test]
fn concurrent_jobs_match_their_solo_runs() {
    let (server, store) = serve_chain("concurrent", 2000, 4, 2, 8);
    let addr = server.addr();

    // Two jobs in flight against the one resident graph.
    let cc = submit(addr, "{\"algo\":\"cc\"}");
    let sssp = submit(addr, "{\"algo\":\"sssp\",\"source\":0}");
    assert_eq!(status_of(&wait_terminal(addr, cc)), "done");
    assert_eq!(status_of(&wait_terminal(addr, sssp)), "done");

    // Both are listed, in id order.
    let (st, list) = get_json(addr, "/v1/jobs");
    assert_eq!(st, 200);
    let list = list.as_array().unwrap().to_vec();
    assert_eq!(list.len(), 2);
    assert_eq!(list[0].get("algo").unwrap().as_str(), Some("cc"));
    assert_eq!(list[1].get("algo").unwrap().as_str(), Some("sssp"));

    // Each result is byte-identical to a solo run of the same spec
    // (default cores = the server's 2).
    for (id, algo) in [(cc, "cc"), (sssp, "sssp")] {
        let solo = Job::builder()
            .algo(algo)
            .cores(2)
            .build()
            .unwrap()
            .run(JobSource::Store(&store))
            .unwrap();
        let (st, got) = http(
            addr,
            "GET",
            &format!("/v1/jobs/{id}/results?limit=100000&format=tsv"),
            "",
        );
        assert_eq!(st, 200);
        assert_eq!(got, tsv_of(&solo.values), "{algo}");
    }

    server.shutdown();
}

#[test]
fn cancellation_stops_within_one_superstep() {
    // Vertex-engine cc on a long chain needs ~n supersteps, so the job
    // is comfortably still running when the DELETE lands.
    let (server, _store) = serve_chain("cancel", 20_000, 4, 1, 4);
    let addr = server.addr();

    let id = submit(addr, "{\"algo\":\"cc\",\"engine\":\"vertex\"}");
    wait_until(addr, id, |v| {
        status_of(v) == "running" && superstep_of(v) >= 1
    });

    let (st, body) = http(addr, "DELETE", &format!("/v1/jobs/{id}"), "");
    assert_eq!(st, 200, "{body}");
    let at_cancel = superstep_of(&JsonValue::parse(&body).unwrap());

    let fin = wait_terminal(addr, id);
    assert_eq!(status_of(&fin), "cancelled", "{fin:?}");
    // The engine honors the cancel at the next barrier: at most one
    // more superstep runs after the DELETE was acknowledged.
    let final_step = superstep_of(&fin);
    assert!(
        final_step <= at_cancel + 1,
        "cancelled at {at_cancel} but ran to {final_step}"
    );

    // Results of a cancelled job are a conflict, and a repeat DELETE is
    // idempotent while a DELETE of a finished job will 409 below.
    let (st, _) = http(addr, "GET", &format!("/v1/jobs/{id}/results"), "");
    assert_eq!(st, 409);
    let (st, _) = http(addr, "DELETE", &format!("/v1/jobs/{id}"), "");
    assert_eq!(st, 200);

    server.shutdown();
}

#[test]
fn admission_is_bounded_and_queued_jobs_cancel_without_running() {
    // One worker, one queue slot: a long job occupies the worker, the
    // next job the slot, and the third submit is refused with 503.
    let (server, _store) = serve_chain("admission", 20_000, 4, 1, 1);
    let addr = server.addr();

    let long = submit(addr, "{\"algo\":\"cc\",\"engine\":\"vertex\"}");
    wait_until(addr, long, |v| status_of(v) == "running");
    let queued = submit(addr, "{\"algo\":\"cc\"}");
    let (st, body) = http(addr, "POST", "/v1/jobs", "{\"algo\":\"cc\"}");
    assert_eq!(st, 503, "{body}");
    assert!(body.contains("admission queue full"), "{body}");

    // The queued job cancels instantly, never having run a superstep.
    let (st, body) = http(addr, "DELETE", &format!("/v1/jobs/{queued}"), "");
    assert_eq!(st, 200);
    let v = JsonValue::parse(&body).unwrap();
    assert_eq!(status_of(&v), "cancelled");
    assert_eq!(superstep_of(&v), 0);

    // Cancel the long job too; once done, a DELETE is a 409.
    let (st, _) = http(addr, "DELETE", &format!("/v1/jobs/{long}"), "");
    assert_eq!(st, 200);
    let fin = wait_terminal(addr, long);
    assert_eq!(status_of(&fin), "cancelled");
    let (st, body) = http(addr, "DELETE", &format!("/v1/jobs/{long}"), "");
    assert_eq!(st, 409, "{body}");

    server.shutdown();
}

#[test]
fn health_graphs_and_error_paths() {
    let (server, _store) = serve_chain("health", 64, 2, 1, 4);
    let addr = server.addr();

    let (st, v) = get_json(addr, "/v1/healthz");
    assert_eq!(st, 200);
    assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("graph").unwrap().as_str(), Some("health"));

    let (st, v) = get_json(addr, "/v1/graphs");
    assert_eq!(st, 200);
    let g = &v.as_array().unwrap()[0];
    assert_eq!(g.get("name").unwrap().as_str(), Some("health"));
    assert_eq!(g.get("vertices").unwrap().as_f64(), Some(64.0));
    assert_eq!(g.get("partitions").unwrap().as_f64(), Some(2.0));
    assert_eq!(g.get("format").unwrap().as_str(), Some("v2"));

    // Error surface: unknown endpoint, wrong method, bad ids, bad
    // bodies, bad query parameters, missing jobs.
    let (st, _) = http(addr, "GET", "/nope", "");
    assert_eq!(st, 404);
    let (st, _) = http(addr, "DELETE", "/v1/healthz", "");
    assert_eq!(st, 405);
    let (st, _) = http(addr, "GET", "/v1/jobs/banana", "");
    assert_eq!(st, 400);
    let (st, _) = http(addr, "GET", "/v1/jobs/7", "");
    assert_eq!(st, 404);
    let (st, body) = http(addr, "POST", "/v1/jobs", "{\"algo\":\"frobnicate\"}");
    assert_eq!(st, 400);
    assert!(body.contains("unknown algorithm"), "{body}");
    let (st, body) = http(addr, "POST", "/v1/jobs", "not json");
    assert_eq!(st, 400);
    assert!(body.contains("bad JSON body"), "{body}");

    // A completed job rejects malformed paging/format parameters.
    let id = submit(addr, "{\"algo\":\"cc\"}");
    wait_terminal(addr, id);
    let (st, _) = http(addr, "GET", &format!("/v1/jobs/{id}/results?offset=x"), "");
    assert_eq!(st, 400);
    let (st, _) = http(addr, "GET", &format!("/v1/jobs/{id}/results?format=xml"), "");
    assert_eq!(st, 400);

    server.shutdown();
}

#[test]
fn refresh_tracks_appended_generations_and_retention_evicts() {
    // A packed (appendable) store served with a retention cap of one
    // held result set.
    let g = gen::chain(64);
    let parts = RangePartitioner.partition(&g, 2);
    let root = tmp("genref");
    Store::create_with_format(&root, "genref", &g, &parts, SliceFormat::V3Packed).unwrap();
    let resident = ResidentGraph::open(&root).unwrap();
    let opts = ServeOptions { port: 0, workers: 1, queue: 8, cores: 2, keep_results: Some(1) };
    let server = Server::start(resident, &opts).unwrap();
    let addr = server.addr();

    let (st, v) = get_json(addr, "/v1/graphs");
    assert_eq!(st, 200);
    let g0 = &v.as_array().unwrap()[0];
    assert_eq!(g0.get("generation").unwrap().as_f64(), Some(0.0));
    assert_eq!(g0.get("vertices").unwrap().as_f64(), Some(64.0));

    // Refreshing a graph the server does not hold is a 404.
    let (st, body) = http(addr, "POST", "/v1/graphs/other/refresh", "");
    assert_eq!(st, 404, "{body}");

    // Job 1 runs against generation 0.
    let j1 = submit(addr, "{\"algo\":\"cc\"}");
    let done = wait_terminal(addr, j1);
    assert_eq!(status_of(&done), "done", "{done:?}");
    assert_eq!(done.get("num_values").unwrap().as_f64(), Some(64.0));
    let (st, _) = http(addr, "GET", &format!("/v1/jobs/{j1}/results?format=tsv"), "");
    assert_eq!(st, 200);

    // Append a new vertex (64) plus an edge to it while the server is
    // up. The existing endpoint must sit on a different partition than
    // the hash-placed new vertex (same-partition cross-sub-graph edges
    // would be a merge, which append rejects). The resident snapshot
    // stays pinned at generation 0…
    let new_part = goffish::partition::HashPartitioner::default().bucket(64, 2);
    let existing: u64 = if new_part == 0 { 63 } else { 0 };
    let mut writer = Store::open(&root).unwrap();
    let committed = writer
        .append(&AppendBatch {
            new_vertices: 1,
            edges: vec![(existing, 64, None)],
            ..Default::default()
        })
        .unwrap();
    assert_eq!(committed, 1);
    let (_, v) = get_json(addr, "/v1/graphs");
    assert_eq!(
        v.as_array().unwrap()[0].get("generation").unwrap().as_f64(),
        Some(0.0),
        "snapshot must stay pinned until an explicit refresh"
    );

    // …until an explicit refresh swaps to the head generation.
    let (st, body) = http(addr, "POST", "/v1/graphs/genref/refresh", "");
    assert_eq!(st, 200, "{body}");
    let v = JsonValue::parse(&body).unwrap();
    assert_eq!(v.get("refreshed").unwrap().as_bool(), Some(true));
    assert_eq!(v.get("previous_generation").unwrap().as_f64(), Some(0.0));
    assert_eq!(v.get("generation").unwrap().as_f64(), Some(1.0));
    assert_eq!(v.get("vertices").unwrap().as_f64(), Some(65.0));

    // Job 2 sees the refreshed graph; its completion trips the
    // retention cap and evicts job 1's values (metrics survive).
    let j2 = submit(addr, "{\"algo\":\"cc\"}");
    let done = wait_terminal(addr, j2);
    assert_eq!(status_of(&done), "done", "{done:?}");
    assert_eq!(done.get("num_values").unwrap().as_f64(), Some(65.0));

    let (st, body) = http(addr, "GET", &format!("/v1/jobs/{j1}/results?format=tsv"), "");
    assert_eq!(st, 410, "{body}");
    let (st, v) = get_json(addr, &format!("/v1/jobs/{j1}"));
    assert_eq!(st, 200);
    assert_eq!(status_of(&v), "done");
    assert_eq!(v.get("results_evicted").unwrap().as_bool(), Some(true));
    let (st, _) = http(addr, "GET", &format!("/v1/jobs/{j2}/results?format=tsv"), "");
    assert_eq!(st, 200, "newest done job keeps its values");

    // Both jobs keep full metrics on the metrics endpoint.
    let (st, v) = get_json(addr, "/v1/metrics");
    assert_eq!(st, 200);
    let rows = v.as_array().unwrap();
    assert_eq!(rows.len(), 2);
    for row in rows {
        assert_eq!(row.get("status").unwrap().as_str(), Some("done"));
        assert!(row.get("supersteps").unwrap().as_f64().unwrap() >= 1.0);
        assert!(row.get("makespan_seconds").is_some());
        assert!(row.get("aggregators").is_some());
    }

    server.shutdown();
}
