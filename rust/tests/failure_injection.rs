//! Failure injection: the system must fail *loudly and cleanly* — no
//! deadlocks, no silent corruption — when programs panic, slices rot,
//! or inputs are malformed.

use std::path::PathBuf;

use goffish::gofs::{subgraph::discover, Store, Subgraph};
use goffish::gopher::{
    run, run_on_store, GopherConfig, IncomingMessage, SubgraphContext, SubgraphProgram,
};
use goffish::graph::gen;
use goffish::partition::{MultilevelPartitioner, Partitioner, Partitioning};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("goffish_failures")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Panics while computing one specific sub-graph at superstep 2.
struct PanicsOnPartition(u32);

impl SubgraphProgram for PanicsOnPartition {
    type Msg = u32;
    type State = ();

    fn init(&self, _sg: &Subgraph) {}

    fn compute(
        &self,
        _state: &mut (),
        sg: &Subgraph,
        ctx: &mut SubgraphContext<'_, u32>,
        _msgs: &[IncomingMessage<u32>],
    ) {
        if ctx.superstep() == 2 && sg.id.partition == self.0 {
            panic!("injected compute failure on partition {}", self.0);
        }
        // Keep everyone active so the panic partition is reached.
        if ctx.superstep() < 3 {
            ctx.send_to_all_neighbors(1);
        } else {
            ctx.vote_to_halt();
        }
    }
}

#[test]
fn compute_panic_aborts_job_without_deadlock() {
    let g = gen::road(12, 0.92, 0.02, 61);
    let parts = MultilevelPartitioner::default().partition(&g, 3);
    let dg = discover(&g, &parts).unwrap();
    for victim in 0..3 {
        let err = match run(&dg, &PanicsOnPartition(victim), &GopherConfig::default()) {
            Err(e) => e,
            Ok(_) => panic!("panicking program must fail the job"),
        };
        assert!(
            err.to_string().contains("panicked"),
            "error should mention the panic: {err:#}"
        );
    }
}

#[test]
fn truncated_slice_fails_load() {
    let g = gen::chain(30);
    let parts = MultilevelPartitioner::default().partition(&g, 2);
    let root = tmp("truncated");
    let (store, _) = Store::create(&root, "c", &g, &parts).unwrap();
    let slice = root.join("host0").join("sg_0.topo.slice");
    let bytes = std::fs::read(&slice).unwrap();
    std::fs::write(&slice, &bytes[..bytes.len() / 2]).unwrap();
    let err = match run_on_store(&store, &goffish::algos::cc::CcSg, &GopherConfig::default()) {
        Err(e) => e,
        Ok(_) => panic!("truncated slice must fail the job"),
    };
    let msg = format!("{err:#}");
    assert!(
        msg.contains("truncated") || msg.contains("checksum") || msg.contains("decode"),
        "unexpected error: {msg}"
    );
}

#[test]
fn missing_slice_file_fails_load() {
    let g = gen::chain(20);
    let parts = MultilevelPartitioner::default().partition(&g, 2);
    let root = tmp("missing");
    let (store, _) = Store::create(&root, "c", &g, &parts).unwrap();
    std::fs::remove_file(root.join("host1").join("sg_0.topo.slice")).unwrap();
    assert!(store.load_partition(1).is_err());
}

#[test]
fn meta_tampering_detected() {
    let g = gen::chain(20);
    let parts = MultilevelPartitioner::default().partition(&g, 2);
    let root = tmp("meta");
    let (_, _) = Store::create(&root, "c", &g, &parts).unwrap();
    // Claim a partition count that doesn't match the subgraph list.
    let meta = std::fs::read_to_string(root.join("meta.txt")).unwrap();
    let tampered = meta.replace("partitions=2", "partitions=5");
    std::fs::write(root.join("meta.txt"), tampered).unwrap();
    assert!(Store::open(&root).is_err());
}

/// Sends to a sub-graph index that does not exist on the target host.
struct MisroutedSender;

impl SubgraphProgram for MisroutedSender {
    type Msg = u32;
    type State = ();

    fn init(&self, _sg: &Subgraph) {}

    fn compute(
        &self,
        _state: &mut (),
        sg: &Subgraph,
        ctx: &mut SubgraphContext<'_, u32>,
        _msgs: &[IncomingMessage<u32>],
    ) {
        if ctx.superstep() == 1 && sg.id.partition == 0 && sg.id.index == 0 {
            ctx.send_to_subgraph(
                goffish::gofs::SubgraphId { partition: 1, index: 9999 },
                42,
            );
        }
        ctx.vote_to_halt();
    }
}

#[test]
fn message_to_unknown_subgraph_is_an_error() {
    let g = gen::chain(10);
    let parts = Partitioning::new(2, vec![0, 0, 0, 0, 0, 1, 1, 1, 1, 1]);
    let dg = discover(&g, &parts).unwrap();
    let err = match run(&dg, &MisroutedSender, &GopherConfig::default()) {
        Err(e) => e,
        Ok(_) => panic!("misrouted message must fail"),
    };
    assert!(
        format!("{err:#}").contains("unknown sub-graph"),
        "unexpected error: {err:#}"
    );
}

#[test]
fn empty_partition_is_harmless() {
    // A partitioning where one host owns nothing must still run.
    let g = gen::chain(6);
    let parts = Partitioning::new(3, vec![0, 0, 0, 1, 1, 1]); // host 2 empty
    let dg = discover(&g, &parts).unwrap();
    let res = run(&dg, &goffish::algos::cc::CcSg, &GopherConfig::default()).unwrap();
    assert_eq!(res.states.len(), dg.num_subgraphs());
}

#[test]
fn zero_vertex_graph_runs() {
    let g = goffish::graph::Graph::from_edges(0, &[], None, false).unwrap();
    let parts = Partitioning::new(1, vec![]);
    let dg = discover(&g, &parts).unwrap();
    let res = run(&dg, &goffish::algos::cc::CcSg, &GopherConfig::default()).unwrap();
    assert!(res.states.is_empty());
}
