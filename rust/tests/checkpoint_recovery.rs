//! Fault-tolerance acceptance tests: superstep checkpoints + rollback
//! recovery must be *exact*.
//!
//! The contract under test (the `crate::ckpt` subsystem threaded
//! through both engines): a job killed at superstep `k` and resumed
//! from its latest committed checkpoint returns a `JobOutput` —
//! per-vertex values **and** aggregator traces — identical to the same
//! job running uninterrupted. That requires deterministic replay
//! (sender-sorted inboxes, worker-ordered aggregator folds), exact
//! state round-trips (`StateCodec`), and coordinator-history restore.

use std::path::PathBuf;

use goffish::ckpt::{CheckpointReader, CheckpointWriter};
use goffish::gofs::{section, Store};
use goffish::graph::gen;
use goffish::job::{EngineKind, Job, JobBuilder, JobOutput, JobSource};
use goffish::partition::{MultilevelPartitioner, Partitioner};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("goffish_ckpt_recovery")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Weighted multi-partition store shared by the drills (weights matter
/// for SSSP; CC/PageRank ignore them).
fn build_store(name: &str) -> Store {
    let g = gen::with_random_weights(&gen::road(14, 0.92, 0.02, 41), 1.0, 10.0, 42);
    let parts = MultilevelPartitioner::default().partition(&g, 3);
    let (store, _) = Store::create(&tmp(name), "ft", &g, &parts).unwrap();
    store
}

fn base_job(algo: &str, engine: EngineKind) -> JobBuilder {
    Job::builder()
        .algo(algo)
        .engine(engine)
        .supersteps(8)
        .source_vertex(0)
}

/// Values and aggregator traces must match exactly — recovery parity is
/// a byte-identical guarantee, not an approximate one.
fn assert_output_identical(a: &JobOutput, b: &JobOutput, label: &str) {
    assert_eq!(a.values, b.values, "{label}: values diverged");
    assert_eq!(
        a.aggregators.len(),
        b.aggregators.len(),
        "{label}: aggregator count diverged"
    );
    for (ta, tb) in a.aggregators.iter().zip(&b.aggregators) {
        assert_eq!(ta.name, tb.name, "{label}");
        assert_eq!(ta.values, tb.values, "{label}: trace {} diverged", ta.name);
    }
}

/// Kill `worker` at superstep `kill_at` with checkpoints every `every`
/// supersteps, resume, and demand output identical to an uninterrupted
/// run.
fn kill_and_resume_drill(
    store: &Store,
    algo: &str,
    engine: EngineKind,
    every: usize,
    kill_at: usize,
    worker: u32,
) {
    let label = format!("{algo}/{engine:?}/every{every}/kill{kill_at}");
    let ckpt = tmp(&format!("drill_{algo}_{engine:?}_{every}_{kill_at}"));

    let baseline = base_job(algo, engine)
        .build()
        .unwrap()
        .run(JobSource::Store(store))
        .unwrap();
    assert!(
        baseline.metrics.num_supersteps() > kill_at,
        "{label}: drill needs a kill before natural termination \
         (job took {} supersteps)",
        baseline.metrics.num_supersteps()
    );

    // The killed run fails loudly with the injected error…
    let err = base_job(algo, engine)
        .checkpoint_every(every)
        .checkpoint_dir(&ckpt)
        .kill_at(kill_at, worker)
        .build()
        .unwrap()
        .run(JobSource::Store(store))
        .expect_err("killed run must fail");
    assert!(
        format!("{err:#}").contains("injected worker failure"),
        "{label}: {err:#}"
    );
    // …having committed exactly the epochs before the kill.
    let reader = CheckpointReader::open(&ckpt).unwrap();
    let latest = reader.latest_valid().unwrap();
    assert!(
        latest as usize == kill_at - 1 || (kill_at - 1) % every != 0,
        "{label}: latest committed epoch {latest}"
    );
    assert!((latest as usize) < kill_at, "{label}");

    // The resumed run executes only the remaining supersteps…
    let resumed = base_job(algo, engine)
        .resume_from(&ckpt)
        .build()
        .unwrap()
        .run(JobSource::Store(store))
        .unwrap();
    assert_eq!(
        resumed.metrics.num_supersteps(),
        baseline.metrics.num_supersteps() - latest as usize,
        "{label}: resumed run re-executed the wrong superstep range"
    );
    // …but its output (values + full aggregator traces) is identical.
    assert_output_identical(&baseline, &resumed, &label);
}

#[test]
fn recovery_parity_cc_both_engines() {
    let store = build_store("cc");
    kill_and_resume_drill(&store, "cc", EngineKind::Gopher, 1, 2, 1);
    kill_and_resume_drill(&store, "cc", EngineKind::Vertex, 1, 2, 1);
}

#[test]
fn recovery_parity_sssp_both_engines() {
    let store = build_store("sssp");
    kill_and_resume_drill(&store, "sssp", EngineKind::Gopher, 1, 2, 0);
    kill_and_resume_drill(&store, "sssp", EngineKind::Vertex, 1, 2, 0);
}

#[test]
fn recovery_parity_pagerank_both_engines() {
    let store = build_store("pagerank");
    // PageRank runs exactly 8 supersteps here: kill mid-run, and also
    // exercise a sparser checkpoint cadence (latest epoch = 4 when
    // killed at 5 with every=2).
    kill_and_resume_drill(&store, "pagerank", EngineKind::Gopher, 1, 3, 2);
    kill_and_resume_drill(&store, "pagerank", EngineKind::Vertex, 1, 3, 2);
    kill_and_resume_drill(&store, "pagerank", EngineKind::Gopher, 2, 5, 1);
}

#[test]
fn recovery_parity_aggregator_driven_jobs() {
    let store = build_store("aggs");
    // Label propagation terminates via the lp_changes aggregator on
    // both engines: the restored coordinator history must reproduce the
    // full trace and the same termination superstep.
    kill_and_resume_drill(&store, "labelprop", EngineKind::Gopher, 1, 2, 1);
    kill_and_resume_drill(&store, "labelprop", EngineKind::Vertex, 1, 2, 1);
}

#[test]
fn recovery_parity_epsilon_pagerank_aggregator_restore() {
    // Aggregator-driven convergence (pr_l1_delta, Gopher-only): the
    // resumed job must observe the restored global delta and halt on
    // the same superstep with the same trace.
    let g = gen::social(300, 4, 0.0, 31);
    let parts = MultilevelPartitioner::default().partition(&g, 3);
    let (store, _) = Store::create(&tmp("eps_pr"), "ft", &g, &parts).unwrap();
    let job = || {
        Job::builder()
            .algo("pagerank")
            .epsilon(0.05)
            .supersteps(60)
    };
    let baseline = job().build().unwrap().run(JobSource::Store(&store)).unwrap();
    let steps = baseline.metrics.num_supersteps();
    assert!(steps >= 4, "drill needs room to kill at superstep 4 (got {steps})");

    let ckpt = tmp("eps_pr_ckpt");
    job()
        .checkpoint_every(1)
        .checkpoint_dir(&ckpt)
        .kill_at(4, 0)
        .build()
        .unwrap()
        .run(JobSource::Store(&store))
        .expect_err("killed run must fail");
    let resumed = job()
        .resume_from(&ckpt)
        .build()
        .unwrap()
        .run(JobSource::Store(&store))
        .unwrap();
    assert_output_identical(&baseline, &resumed, "pagerank+epsilon");
    let trace = resumed
        .metrics
        .aggregator(goffish::algos::pagerank::AGG_L1_DELTA)
        .expect("restored delta trace");
    assert_eq!(trace.values.len(), steps, "trace covers the whole logical run");
}

#[test]
fn checkpoint_metrics_recorded_and_resume_continues_checkpointing() {
    let store = build_store("metrics");
    let ckpt = tmp("metrics_ckpt");
    let out = base_job("pagerank", EngineKind::Gopher)
        .checkpoint_every(2)
        .checkpoint_dir(&ckpt)
        .build()
        .unwrap()
        .run(JobSource::Store(&store))
        .unwrap();
    // 8 supersteps, cadence 2 → epochs 2, 4, 6, 8.
    let epochs: Vec<usize> = out.metrics.checkpoints.iter().map(|c| c.superstep).collect();
    assert_eq!(epochs, vec![2, 4, 6, 8]);
    assert!(out.metrics.checkpoint_bytes() > 0);
    assert!(out.metrics.checkpoint_seconds() > 0.0);
    assert!(out.metrics.report("pr").contains("ckpt[4 epochs"));

    // A resumed run with a cadence (but no explicit dir) keeps
    // committing into the directory it resumed from; epoch numbering
    // continues from the restored superstep.
    let killed = base_job("pagerank", EngineKind::Gopher)
        .checkpoint_every(2)
        .checkpoint_dir(&ckpt2(&ckpt))
        .kill_at(5, 0)
        .build()
        .unwrap()
        .run(JobSource::Store(&store));
    killed.expect_err("killed");
    let resumed = base_job("pagerank", EngineKind::Gopher)
        .checkpoint_every(2)
        .resume_from(&ckpt2(&ckpt))
        .build()
        .unwrap()
        .run(JobSource::Store(&store))
        .unwrap();
    // Resumed from epoch 4: re-runs supersteps 5..8, checkpoints 6 and 8.
    let epochs: Vec<usize> =
        resumed.metrics.checkpoints.iter().map(|c| c.superstep).collect();
    assert_eq!(epochs, vec![6, 8]);
    let reader = CheckpointReader::open(&ckpt2(&ckpt)).unwrap();
    assert_eq!(reader.latest_valid().unwrap(), 8);
}

fn ckpt2(base: &std::path::Path) -> PathBuf {
    base.with_file_name(format!(
        "{}_resume",
        base.file_name().unwrap().to_string_lossy()
    ))
}

#[test]
fn corrupt_epoch_falls_back_to_previous_and_still_recovers_exactly() {
    let store = build_store("fallback");
    let ckpt = tmp("fallback_ckpt");
    let baseline = base_job("pagerank", EngineKind::Gopher)
        .build()
        .unwrap()
        .run(JobSource::Store(&store))
        .unwrap();
    base_job("pagerank", EngineKind::Gopher)
        .checkpoint_every(1)
        .checkpoint_dir(&ckpt)
        .kill_at(4, 1)
        .build()
        .unwrap()
        .run(JobSource::Store(&store))
        .expect_err("killed run must fail");

    // Committed epochs (retention keeps the last two): 2 and 3. Rot one
    // section of epoch 3's worker-1 snapshot.
    let reader = CheckpointReader::open(&ckpt).unwrap();
    assert_eq!(reader.manifest().epochs, vec![2, 3]);
    let victim = reader.partition_path(3, 1);
    let mut bytes = std::fs::read(&victim).unwrap();
    let ranges = section::unframe(
        &bytes,
        goffish::ckpt::MAGIC,
        goffish::ckpt::VERSION,
        0, // partition snapshot kind
        |_| "section",
    )
    .unwrap()
    .ranges();
    let (_, states_range) = ranges[1].clone();
    bytes[states_range.start + states_range.len() / 2] ^= 0x55;
    std::fs::write(&victim, bytes).unwrap();

    // Direct validation names the corrupt file; recovery silently falls
    // back to epoch 2 and still reproduces the baseline exactly.
    let err = reader.validate_epoch(3).unwrap_err();
    assert!(format!("{err:#}").contains("part_1.ckpt"), "{err:#}");
    assert_eq!(reader.latest_valid().unwrap(), 2);
    let resumed = base_job("pagerank", EngineKind::Gopher)
        .resume_from(&ckpt)
        .build()
        .unwrap()
        .run(JobSource::Store(&store))
        .unwrap();
    assert_output_identical(&baseline, &resumed, "fallback");
    // It re-ran supersteps 3..8 (6 of the 8), not just 4..8.
    assert_eq!(resumed.metrics.num_supersteps(), 6);
}

#[test]
fn deterministic_replay_across_identical_runs() {
    // The underpinning of recovery parity: two identical runs produce
    // identical outputs, including float-summing PageRank (sender-sorted
    // inboxes + worker-ordered aggregator folds).
    let store = build_store("determinism");
    for engine in [EngineKind::Gopher, EngineKind::Vertex] {
        let run = || {
            base_job("pagerank", engine)
                .build()
                .unwrap()
                .run(JobSource::Store(&store))
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_output_identical(&a, &b, &format!("determinism/{engine:?}"));
    }
}

#[test]
fn writer_refuses_foreign_directories_end_to_end() {
    // A checkpoint directory carries its job identity: checkpointing a
    // different job into it must fail before any epoch is written.
    let store = build_store("foreign");
    let dir = tmp("foreign_ckpt");
    CheckpointWriter::create(&dir, "somethingelse/gopher", 3, false).unwrap();
    let err = base_job("cc", EngineKind::Gopher)
        .checkpoint_every(1)
        .checkpoint_dir(&dir)
        .build()
        .unwrap()
        .run(JobSource::Store(&store))
        .expect_err("foreign dir must be refused");
    assert!(format!("{err:#}").contains("belongs to job"), "{err:#}");
}
