//! Full-pipeline integration: generate → partition → GoFS store →
//! Gopher run from disk → verify results + metrics, over temp dirs.

use std::path::PathBuf;

use goffish::algos::cc::{count_components, CcSg};
use goffish::algos::sssp::SsspSg;
use goffish::algos::{gather_subgraph_values, gather_vertex_values};
use goffish::gofs::Store;
use goffish::gopher::{run_on_store, FabricKind, GopherConfig};
use goffish::graph::{gen, props};
use goffish::partition::{MultilevelPartitioner, Partitioner};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("goffish_integration")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn pipeline_cc_from_disk() {
    let g = gen::road(24, 0.92, 0.015, 101);
    let parts = MultilevelPartitioner::default().partition(&g, 4);
    let root = tmp("cc");
    let (store, dg) = Store::create(&root, "rn-analog", &g, &parts).unwrap();

    // Run entirely from disk (data-local load) like a real deployment.
    let res = run_on_store(&store, &CcSg, &GopherConfig::default()).unwrap();
    assert!(res.metrics.load_bytes > 0);
    assert!(res.metrics.load_files as usize == dg.num_subgraphs());
    assert!(res.metrics.load_seconds > 0.0);

    let labels = gather_subgraph_values(&dg, &res.states);
    assert_eq!(count_components(&labels), props::wcc_count(&g));
    for (u, v, _) in g.edges() {
        assert_eq!(labels[u as usize], labels[v as usize]);
    }
}

#[test]
fn pipeline_sssp_from_disk_over_tcp() {
    let g = gen::with_random_weights(&gen::road(16, 0.94, 0.02, 7), 1.0, 8.0, 9);
    let parts = MultilevelPartitioner::default().partition(&g, 3);
    let root = tmp("sssp_tcp");
    let (store, dg) = Store::create(&root, "rn-w", &g, &parts).unwrap();
    let cfg = GopherConfig { fabric: FabricKind::Tcp, ..Default::default() };
    let res = run_on_store(&store, &SsspSg { source: 0 }, &cfg).unwrap();
    let states: std::collections::BTreeMap<_, Vec<f32>> =
        res.states.into_iter().map(|(id, s)| (id, s.dist)).collect();
    let dist = gather_vertex_values(&dg, &states);
    // Spot-check against BFS reachability (weights >= 1 so reachable
    // vertices have finite distance, unreachable infinite).
    let bfs = props::bfs_distances(&g, 0);
    for v in 0..g.num_vertices() {
        assert_eq!(
            dist[v].is_finite(),
            bfs[v] != u32::MAX,
            "vertex {v}: dist={} bfs={}",
            dist[v],
            bfs[v]
        );
        if bfs[v] != u32::MAX {
            assert!(dist[v] >= bfs[v] as f32 * 0.99, "distance below hop bound");
        }
    }
}

#[test]
fn store_reopen_preserves_everything() {
    let g = gen::trace(800, 25, 0.2, 3);
    let parts = MultilevelPartitioner::default().partition(&g, 3);
    let root = tmp("reopen");
    let (_, dg) = Store::create(&root, "tr", &g, &parts).unwrap();

    let store2 = Store::open(&root).unwrap();
    let (dg2, _) = store2.load_all().unwrap();
    assert_eq!(dg.num_subgraphs(), dg2.num_subgraphs());
    assert_eq!(dg.num_global_vertices, dg2.num_global_vertices);
    // Remote refs resolve identically.
    for (a, b) in dg.subgraphs().zip(dg2.subgraphs()) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.vertices, b.vertices);
        assert_eq!(a.remote_out.len(), b.remote_out.len());
        assert_eq!(a.neighbor_subgraphs(), b.neighbor_subgraphs());
    }
    // Meta graphs match too.
    assert_eq!(dg.meta_graph().num_edges(), dg2.meta_graph().num_edges());
}

#[test]
fn metrics_account_supersteps_and_bytes() {
    let g = gen::grid(20, 20);
    let parts = MultilevelPartitioner::default().partition(&g, 4);
    let root = tmp("metrics");
    let (store, _) = Store::create(&root, "grid", &g, &parts).unwrap();
    let res = run_on_store(&store, &CcSg, &GopherConfig::default()).unwrap();
    let m = &res.metrics;
    assert!(m.num_supersteps() >= 2);
    assert!(m.total_messages() > 0);
    assert!(m.total_bytes() > 0);
    assert!(m.compute_seconds > 0.0);
    for ss in &m.supersteps {
        assert_eq!(ss.partition_compute_seconds.len(), 4);
    }
    // Superstep 1 runs every sub-graph.
    assert_eq!(
        m.supersteps[0].active_units,
        store.meta().subgraph_counts.iter().map(|&c| c as u64).sum::<u64>()
    );
}
