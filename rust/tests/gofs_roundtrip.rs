//! GoFS store round-trips under randomized graphs/partitionings, and the
//! paper's structural invariants hold after a disk round-trip — plus the
//! slice-v2 guarantees: v1↔v2 cross-version compat (v1 bytes pinned by a
//! golden), per-section corruption detection, parallel/sequential load
//! equivalence, and strictly-fewer-bytes attribute projection.

use std::path::PathBuf;

use goffish::gofs::{
    slice, subgraph::discover, AttrProjection, DistributedGraph, LoadOptions,
    SliceFormat, Store, Subgraph, SubgraphId,
};
use goffish::graph::{gen, props, Graph};
use goffish::partition::{
    HashPartitioner, MultilevelPartitioner, Partitioner, RangePartitioner,
};
use goffish::util::rng::Rng;

fn tmp(name: &str, case: usize) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("goffish_gofs_rt")
        .join(format!("{name}_{case}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn random_graph(rng: &mut Rng) -> Graph {
    match rng.index(3) {
        0 => gen::road(6 + rng.index(12), 0.8 + rng.f64() * 0.19, 0.03, rng.next_u64()),
        1 => gen::social(80 + rng.index(200), 2 + rng.index(3), rng.f64() * 0.15, rng.next_u64()),
        _ => gen::erdos_renyi(40 + rng.index(100), 0.03, rng.chance(0.5), rng.next_u64()),
    }
}

#[test]
fn randomized_store_roundtrip_preserves_structure() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..10 {
        let weighted = rng.chance(0.5);
        let g0 = random_graph(&mut rng);
        let g = if weighted {
            gen::with_random_weights(&g0, 0.1, 9.9, rng.next_u64())
        } else {
            g0
        };
        let k = 2 + rng.index(4);
        let parts: Box<dyn Partitioner> = match rng.index(3) {
            0 => Box::new(HashPartitioner::new(rng.next_u64())),
            1 => Box::new(RangePartitioner),
            _ => Box::new(MultilevelPartitioner::new(rng.next_u64())),
        };
        let p = parts.partition(&g, k);
        let fmt = if rng.chance(0.5) { SliceFormat::V1 } else { SliceFormat::V2 };
        let root = tmp("rand", case);
        let (store, dg) = Store::create_with_format(&root, "g", &g, &p, fmt).unwrap();
        let (dg2, stats) = store.load_all().unwrap();

        // Invariant 1: vertex partition-of-partitions.
        let mut seen = vec![0u32; g.num_vertices()];
        for sg in dg2.subgraphs() {
            for &v in &sg.vertices {
                seen[v as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "case {case}: vertex coverage");

        // Invariant 2: edge conservation (local + remote_out = all).
        let local_edges: usize = dg2.subgraphs().map(|s| s.local.num_edges()).sum();
        let remote_edges: usize = dg2.subgraphs().map(|s| s.remote_out.len()).sum();
        assert_eq!(local_edges + remote_edges, g.num_edges(), "case {case}: edges");

        // Invariant 3: remote refs resolve to the correct sub-graph.
        for sg in dg2.subgraphs() {
            for r in &sg.remote_out {
                let target = &dg2.partitions[r.partition as usize][r.subgraph as usize];
                assert!(
                    target.local_id(r.target_global).is_some(),
                    "case {case}: remote ref {} not in {}",
                    r.target_global,
                    target.id
                );
            }
        }

        // Invariant 4: sub-graph count bounded by WCC structure: at least
        // the number of WCCs overall, at most the vertex count.
        assert!(dg2.num_subgraphs() >= props::wcc_count(&g));
        assert!(dg2.num_subgraphs() <= g.num_vertices());

        // Invariant 5: byte accounting matches files on disk.
        assert_eq!(stats.files as usize, dg.num_subgraphs());
        assert!(stats.bytes > 0);
    }
}

fn subgraph_shapes(d: &DistributedGraph) -> Vec<(Vec<u32>, Vec<(u32, u32, f32)>, usize, usize)> {
    d.subgraphs()
        .map(|s| {
            let edges: Vec<(u32, u32, f32)> = s
                .local
                .edges()
                .map(|(u, v, ei)| (u, v, s.local.weight(ei)))
                .collect();
            (s.vertices.clone(), edges, s.remote_out.len(), s.remote_in.len())
        })
        .collect()
}

#[test]
fn v1_and_v2_stores_load_identically() {
    // The same graph + partitioning written in both formats must read
    // back as the same distributed graph, edge for edge.
    let g = gen::with_random_weights(&gen::road(16, 0.92, 0.02, 31), 0.5, 9.5, 13);
    let p = MultilevelPartitioner::default().partition(&g, 3);
    let (store_v1, _) = Store::create_with_format(&tmp("xver_v1", 0), "g", &g, &p, SliceFormat::V1).unwrap();
    let (store_v2, _) = Store::create_with_format(&tmp("xver_v2", 0), "g", &g, &p, SliceFormat::V2).unwrap();
    let (dg1, st1) = store_v1.load_all().unwrap();
    let (dg2, st2) = store_v2.load_all().unwrap();
    assert_eq!(subgraph_shapes(&dg1), subgraph_shapes(&dg2));
    assert_eq!(st1.files, st2.files);
    // And each decoder accepts the other writer's sub-graphs directly.
    for sg in dg1.subgraphs() {
        let via_v2 = slice::decode_topology(&slice::encode_topology(sg, SliceFormat::V2)).unwrap();
        assert_eq!(via_v2.vertices, sg.vertices);
    }
}

#[test]
fn v1_encoding_is_frozen_byte_for_byte() {
    // Golden bytes computed independently (Python replica of the v1
    // codec): any drift in the v1 writer would orphan existing stores.
    let local = Graph::from_edges(2, &[(0, 1)], None, false).unwrap();
    let sg = Subgraph {
        id: SubgraphId { partition: 0, index: 0 },
        vertices: vec![0, 1],
        local,
        remote_out: vec![],
        remote_in: vec![],
        num_global_vertices: 2,
    };
    let golden: Vec<u8> = vec![
        71, 70, 83, 76, // "GFSL"
        1, 0, // version 1, kind topology
        13, // payload length (varint)
        134, 206, 142, 172, 148, 179, 219, 182, 67, // FNV-1a 64 (varint)
        0, 0, 2, 0, 0, // id, |V| global, directed, weighted
        2, 0, 1, // sorted vertex ids (delta)
        1, 0, 1, // one edge (0, 1)
        0, 0, // no remote out / in
    ];
    assert_eq!(slice::encode_topology(&sg, SliceFormat::V1), golden);
    let back = slice::decode_topology(&golden).unwrap();
    assert_eq!(back.vertices, vec![0, 1]);
    assert_eq!(back.local.num_edges(), 1);
}

#[test]
fn v2_per_section_corruption_names_the_section() {
    // Weighted graph with cross-partition edges: every section of the
    // v2 layout is non-empty. Flip one byte inside each section and the
    // decode error must name exactly that section.
    let g = gen::with_random_weights(&gen::road(14, 0.9, 0.02, 17), 1.0, 5.0, 3);
    let p = RangePartitioner.partition(&g, 3);
    let dg = discover(&g, &p).unwrap();
    let sg = dg
        .subgraphs()
        .find(|s| {
            s.local.num_edges() > 0 && (!s.remote_out.is_empty() || !s.remote_in.is_empty())
        })
        .expect("a boundary sub-graph with local edges");
    let bytes = slice::encode_topology(sg, SliceFormat::V2);
    let sections = slice::section_ranges(&bytes).unwrap();
    let names: Vec<&str> = sections.iter().map(|(n, _)| *n).collect();
    for want in ["meta", "vertices", "offsets", "targets", "weights", "remote_out", "remote_in"] {
        assert!(names.contains(&want), "missing section {want} in {names:?}");
    }
    let mut checked = 0;
    for (name, range) in sections {
        if range.is_empty() {
            continue;
        }
        let mut bad = bytes.clone();
        bad[range.start + range.len() / 2] ^= 0xff;
        let err = slice::decode_topology(&bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(name), "corrupting `{name}` gave: {msg}");
        checked += 1;
    }
    assert!(checked >= 6, "only {checked} sections exercised");

    // Truncation inside the last section is named too.
    let err = slice::decode_topology(&bytes[..bytes.len() - 1]).unwrap_err();
    assert!(
        format!("{err:#}").contains("truncated") || format!("{err:#}").contains("trailing"),
        "{err:#}"
    );
}

#[test]
fn parallel_and_sequential_load_all_agree() {
    let g = gen::social(300, 3, 0.05, 41);
    let p = MultilevelPartitioner::default().partition(&g, 4);
    let (store, _) = Store::create(&tmp("parseq", 0), "g", &g, &p).unwrap();
    let seq = LoadOptions { sequential: true, ..Default::default() };
    let (dg_seq, _, st_seq) = store.load_all_with(&seq).unwrap();
    let (dg_par, _, st_par) = store.load_all_with(&LoadOptions::default()).unwrap();
    assert_eq!(subgraph_shapes(&dg_seq), subgraph_shapes(&dg_par));
    assert_eq!(st_seq.files, st_par.files);
    assert_eq!(st_seq.bytes, st_par.bytes);
}

#[test]
fn projected_attribute_load_reads_strictly_fewer_bytes() {
    // The paper's scenario: ten attributes on disk, the job needs one.
    let g = gen::road(12, 0.9, 0.02, 19);
    let p = MultilevelPartitioner::default().partition(&g, 2);
    let (store, dg) = Store::create(&tmp("proj", 0), "g", &g, &p).unwrap();
    for sg in dg.subgraphs() {
        let vals: Vec<f32> = sg.vertices.iter().map(|&v| v as f32).collect();
        for a in 0..10 {
            store.write_attribute(sg.id, &format!("attr{a}"), &vals).unwrap();
        }
    }
    let full = LoadOptions { attributes: AttrProjection::All, ..Default::default() };
    let one = LoadOptions {
        attributes: AttrProjection::Only(vec!["attr3".into()]),
        ..Default::default()
    };
    let (_, attrs_full, st_full) = store.load_all_with(&full).unwrap();
    let (_, attrs_one, st_one) = store.load_all_with(&one).unwrap();
    assert!(
        st_one.bytes < st_full.bytes,
        "projected {} B must be < full {} B",
        st_one.bytes,
        st_full.bytes
    );
    // The projected load still yields correct, aligned columns.
    let n_sgs = dg.num_subgraphs();
    assert_eq!(attrs_full.iter().map(|p| p.len()).sum::<usize>(), n_sgs);
    assert_eq!(attrs_one.iter().map(|p| p.len()).sum::<usize>(), n_sgs);
    for (p_idx, part) in attrs_one.iter().enumerate() {
        for (i, cols) in part.iter().enumerate() {
            let sg = &dg.partitions[p_idx][i];
            assert_eq!(cols.len(), 1);
            let want: Vec<f32> = sg.vertices.iter().map(|&v| v as f32).collect();
            assert_eq!(cols["attr3"], want);
        }
    }
}

#[test]
fn slice_bytes_scale_with_subgraph_size() {
    // GoFS co-design: per-slice cost tracks topology size, so loading a
    // single attribute/topology slice touches only the needed bytes.
    let g = gen::road(30, 0.95, 0.01, 5);
    let parts = MultilevelPartitioner::default().partition(&g, 2);
    let root = tmp("scale", 0);
    let (store, dg) = Store::create(&root, "g", &g, &parts).unwrap();
    let (_, stats) = store.load_all().unwrap();
    // Compact codec: under ~12 bytes per vertex+edge at this density.
    let entities = g.num_vertices() + g.num_edges() * 2;
    assert!(
        stats.bytes < (entities * 12) as u64,
        "bytes={} entities={}",
        stats.bytes,
        entities
    );
    let _ = dg;
}
