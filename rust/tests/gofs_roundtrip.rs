//! GoFS store round-trips under randomized graphs/partitionings, and the
//! paper's structural invariants hold after a disk round-trip — plus the
//! slice-v2 guarantees (v1↔v2 cross-version compat with v1 bytes pinned
//! by a golden, per-section corruption detection, parallel/sequential
//! load equivalence, strictly-fewer-bytes attribute projection) and the
//! packed-v3 battery: a full corruption matrix over every section kind
//! plus the directory and kind byte, and exact seek-skip byte
//! accounting against the packed directory.

use std::path::PathBuf;

use goffish::gofs::{
    packed, slice, subgraph::discover, AttrProjection, DistributedGraph, LoadOptions,
    SliceFormat, Store, Subgraph, SubgraphId,
};
use goffish::graph::{gen, props, Graph};
use goffish::partition::{
    HashPartitioner, MultilevelPartitioner, Partitioner, RangePartitioner,
};
use goffish::testing::fixtures;
use goffish::util::rng::Rng;

fn tmp(name: &str, case: usize) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("goffish_gofs_rt")
        .join(format!("{name}_{case}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn random_graph(rng: &mut Rng) -> Graph {
    fixtures::random_graph(rng)
}

#[test]
fn randomized_store_roundtrip_preserves_structure() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..10 {
        let weighted = rng.chance(0.5);
        let g0 = random_graph(&mut rng);
        let g = if weighted {
            gen::with_random_weights(&g0, 0.1, 9.9, rng.next_u64())
        } else {
            g0
        };
        let k = 2 + rng.index(4);
        let parts: Box<dyn Partitioner> = match rng.index(3) {
            0 => Box::new(HashPartitioner::new(rng.next_u64())),
            1 => Box::new(RangePartitioner),
            _ => Box::new(MultilevelPartitioner::new(rng.next_u64())),
        };
        let p = parts.partition(&g, k);
        let fmt = match rng.index(3) {
            0 => SliceFormat::V1,
            1 => SliceFormat::V2,
            _ => SliceFormat::V3Packed,
        };
        let root = tmp("rand", case);
        let (store, dg) = Store::create_with_format(&root, "g", &g, &p, fmt).unwrap();
        let (dg2, stats) = store.load_all().unwrap();

        // Invariant 1: vertex partition-of-partitions.
        let mut seen = vec![0u32; g.num_vertices()];
        for sg in dg2.subgraphs() {
            for &v in &sg.vertices {
                seen[v as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "case {case}: vertex coverage");

        // Invariant 2: edge conservation (local + remote_out = all).
        let local_edges: usize = dg2.subgraphs().map(|s| s.local.num_edges()).sum();
        let remote_edges: usize = dg2.subgraphs().map(|s| s.remote_out.len()).sum();
        assert_eq!(local_edges + remote_edges, g.num_edges(), "case {case}: edges");

        // Invariant 3: remote refs resolve to the correct sub-graph.
        for sg in dg2.subgraphs() {
            for r in &sg.remote_out {
                let target = &dg2.partitions[r.partition as usize][r.subgraph as usize];
                assert!(
                    target.local_id(r.target_global).is_some(),
                    "case {case}: remote ref {} not in {}",
                    r.target_global,
                    target.id
                );
            }
        }

        // Invariant 4: sub-graph count bounded by WCC structure: at least
        // the number of WCCs overall, at most the vertex count.
        assert!(dg2.num_subgraphs() >= props::wcc_count(&g));
        assert!(dg2.num_subgraphs() <= g.num_vertices());

        // Invariant 5: byte accounting matches files on disk — one
        // file per slice (v1/v2) or one per partition (v3 packed).
        let want_files = if fmt == SliceFormat::V3Packed {
            dg.partitions.len()
        } else {
            dg.num_subgraphs()
        };
        assert_eq!(stats.files as usize, want_files, "case {case} ({fmt})");
        assert!(stats.bytes > 0);
    }
}

fn subgraph_shapes(d: &DistributedGraph) -> Vec<(Vec<u32>, Vec<(u32, u32, f32)>, usize, usize)> {
    d.subgraphs()
        .map(|s| {
            let edges: Vec<(u32, u32, f32)> = s
                .local
                .edges()
                .map(|(u, v, ei)| (u, v, s.local.weight(ei)))
                .collect();
            (s.vertices.clone(), edges, s.remote_out.len(), s.remote_in.len())
        })
        .collect()
}

#[test]
fn v1_and_v2_stores_load_identically() {
    // The same graph + partitioning written in both formats must read
    // back as the same distributed graph, edge for edge.
    let g = gen::with_random_weights(&gen::road(16, 0.92, 0.02, 31), 0.5, 9.5, 13);
    let p = MultilevelPartitioner::default().partition(&g, 3);
    let (store_v1, _) = Store::create_with_format(&tmp("xver_v1", 0), "g", &g, &p, SliceFormat::V1).unwrap();
    let (store_v2, _) = Store::create_with_format(&tmp("xver_v2", 0), "g", &g, &p, SliceFormat::V2).unwrap();
    let (dg1, st1) = store_v1.load_all().unwrap();
    let (dg2, st2) = store_v2.load_all().unwrap();
    assert_eq!(subgraph_shapes(&dg1), subgraph_shapes(&dg2));
    assert_eq!(st1.files, st2.files);
    // And each decoder accepts the other writer's sub-graphs directly.
    for sg in dg1.subgraphs() {
        let via_v2 = slice::decode_topology(&slice::encode_topology(sg, SliceFormat::V2)).unwrap();
        assert_eq!(via_v2.vertices, sg.vertices);
    }
}

#[test]
fn v1_encoding_is_frozen_byte_for_byte() {
    // Golden bytes computed independently (Python replica of the v1
    // codec): any drift in the v1 writer would orphan existing stores.
    let local = Graph::from_edges(2, &[(0, 1)], None, false).unwrap();
    let sg = Subgraph {
        id: SubgraphId { partition: 0, index: 0 },
        vertices: vec![0, 1],
        local,
        remote_out: vec![],
        remote_in: vec![],
        num_global_vertices: 2,
    };
    let golden: Vec<u8> = vec![
        71, 70, 83, 76, // "GFSL"
        1, 0, // version 1, kind topology
        13, // payload length (varint)
        134, 206, 142, 172, 148, 179, 219, 182, 67, // FNV-1a 64 (varint)
        0, 0, 2, 0, 0, // id, |V| global, directed, weighted
        2, 0, 1, // sorted vertex ids (delta)
        1, 0, 1, // one edge (0, 1)
        0, 0, // no remote out / in
    ];
    assert_eq!(slice::encode_topology(&sg, SliceFormat::V1), golden);
    let back = slice::decode_topology(&golden).unwrap();
    assert_eq!(back.vertices, vec![0, 1]);
    assert_eq!(back.local.num_edges(), 1);
}

#[test]
fn v2_per_section_corruption_names_the_section() {
    // Weighted graph with cross-partition edges: every section of the
    // v2 layout is non-empty. Flip one byte inside each section and the
    // decode error must name exactly that section.
    let g = gen::with_random_weights(&gen::road(14, 0.9, 0.02, 17), 1.0, 5.0, 3);
    let p = RangePartitioner.partition(&g, 3);
    let dg = discover(&g, &p).unwrap();
    let sg = dg
        .subgraphs()
        .find(|s| {
            s.local.num_edges() > 0 && (!s.remote_out.is_empty() || !s.remote_in.is_empty())
        })
        .expect("a boundary sub-graph with local edges");
    let bytes = slice::encode_topology(sg, SliceFormat::V2);
    let sections = slice::section_ranges(&bytes).unwrap();
    let names: Vec<&str> = sections.iter().map(|(n, _)| *n).collect();
    for want in ["meta", "vertices", "offsets", "targets", "weights", "remote_out", "remote_in"] {
        assert!(names.contains(&want), "missing section {want} in {names:?}");
    }
    let mut checked = 0;
    for (name, range) in sections {
        if range.is_empty() {
            continue;
        }
        let mut bad = bytes.clone();
        bad[range.start + range.len() / 2] ^= 0xff;
        let err = slice::decode_topology(&bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains(name), "corrupting `{name}` gave: {msg}");
        checked += 1;
    }
    assert!(checked >= 6, "only {checked} sections exercised");

    // Truncation inside the last section is named too.
    let err = slice::decode_topology(&bytes[..bytes.len() - 1]).unwrap_err();
    assert!(
        format!("{err:#}").contains("truncated") || format!("{err:#}").contains("trailing"),
        "{err:#}"
    );
}

#[test]
fn parallel_and_sequential_load_all_agree() {
    let g = gen::social(300, 3, 0.05, 41);
    let p = MultilevelPartitioner::default().partition(&g, 4);
    let (store, _) = Store::create(&tmp("parseq", 0), "g", &g, &p).unwrap();
    let seq = LoadOptions { sequential: true, ..Default::default() };
    let (dg_seq, _, st_seq) = store.load_all_with(&seq).unwrap();
    let (dg_par, _, st_par) = store.load_all_with(&LoadOptions::default()).unwrap();
    assert_eq!(subgraph_shapes(&dg_seq), subgraph_shapes(&dg_par));
    assert_eq!(st_seq.files, st_par.files);
    assert_eq!(st_seq.bytes, st_par.bytes);
}

#[test]
fn projected_attribute_load_reads_strictly_fewer_bytes() {
    // The paper's scenario: ten attributes on disk, the job needs one.
    let g = gen::road(12, 0.9, 0.02, 19);
    let p = MultilevelPartitioner::default().partition(&g, 2);
    let (store, dg) = Store::create(&tmp("proj", 0), "g", &g, &p).unwrap();
    for sg in dg.subgraphs() {
        let vals: Vec<f32> = sg.vertices.iter().map(|&v| v as f32).collect();
        for a in 0..10 {
            store.write_attribute(sg.id, &format!("attr{a}"), &vals).unwrap();
        }
    }
    let full = LoadOptions { attributes: AttrProjection::All, ..Default::default() };
    let one = LoadOptions {
        attributes: AttrProjection::Only(vec!["attr3".into()]),
        ..Default::default()
    };
    let (_, attrs_full, st_full) = store.load_all_with(&full).unwrap();
    let (_, attrs_one, st_one) = store.load_all_with(&one).unwrap();
    assert!(
        st_one.bytes < st_full.bytes,
        "projected {} B must be < full {} B",
        st_one.bytes,
        st_full.bytes
    );
    // The projected load still yields correct, aligned columns.
    let n_sgs = dg.num_subgraphs();
    assert_eq!(attrs_full.iter().map(|p| p.len()).sum::<usize>(), n_sgs);
    assert_eq!(attrs_one.iter().map(|p| p.len()).sum::<usize>(), n_sgs);
    for (p_idx, part) in attrs_one.iter().enumerate() {
        for (i, cols) in part.iter().enumerate() {
            let sg = &dg.partitions[p_idx][i];
            assert_eq!(cols.len(), 1);
            let want: Vec<f32> = sg.vertices.iter().map(|&v| v as f32).collect();
            assert_eq!(cols["attr3"], want);
        }
    }
}

/// Build a weighted, multi-partition packed store with boundary
/// sub-graphs and two attribute columns, so *every* section kind of
/// the v3 layout (meta, vertices, offsets, targets, weights,
/// remote_out, remote_in, attr values) is present and non-empty
/// somewhere in host0's packed file.
fn packed_store_with_all_sections(
    tag: &str,
) -> (Store, DistributedGraph, PathBuf) {
    let g = gen::with_random_weights(&gen::road(14, 0.9, 0.02, 17), 1.0, 5.0, 3);
    let p = RangePartitioner.partition(&g, 2);
    let root = tmp(tag, 0);
    let (store, dg) =
        Store::create_with_format(&root, "g", &g, &p, SliceFormat::V3Packed).unwrap();
    let mut items = Vec::new();
    for sg in dg.subgraphs() {
        for a in 0..2 {
            let vals: Vec<f32> =
                sg.vertices.iter().map(|&v| v as f32 + a as f32).collect();
            items.push((sg.id, format!("attr{a}"), vals));
        }
    }
    store.write_attributes(&items).unwrap();
    (store, dg, root)
}

#[test]
fn packed_corruption_matrix_names_file_and_section() {
    // Flip one byte in EVERY section body of a packed file, and in its
    // directory and kind byte: each flip must fail the load, and
    // `store verify` (Store::scrub) must name the exact file and
    // section — while the untouched partition keeps loading.
    let (store, _, root) = packed_store_with_all_sections("packed_matrix");
    let victim = root.join("host0").join(packed::PARTITION_FILE);
    let clean = std::fs::read(&victim).unwrap();
    let dir = packed::parse(&clean).unwrap();

    // Every section kind of the layout is exercised at least once.
    let labels: Vec<String> = dir.entries.iter().map(|e| e.label()).collect();
    for want in [
        ".meta", ".vertices", ".offsets", ".targets", ".weights",
        ".remote_out", ".remote_in", ".attr.attr0", ".attr.attr1",
    ] {
        assert!(
            labels.iter().any(|l| l.contains(want)),
            "no section matching {want} in {labels:?}"
        );
    }

    let all = LoadOptions { attributes: AttrProjection::All, ..Default::default() };
    let mut flipped = 0;
    for e in &dir.entries {
        if e.len == 0 {
            continue; // nothing to flip inside an empty section
        }
        let mut bad = clean.clone();
        let r = e.range();
        bad[r.start + r.len() / 2] ^= 0x55;
        std::fs::write(&victim, &bad).unwrap();

        let err = store.load_partition_with(0, &all).unwrap_err();
        assert!(
            format!("{err:#}").contains(&e.label()),
            "flip in {}: load error does not name it: {err:#}",
            e.label()
        );
        let sum = store.scrub().unwrap();
        assert_eq!(sum.corrupt.len(), 1, "flip in {}: {:?}", e.label(), sum.corrupt);
        assert!(sum.corrupt[0].contains("host0/partition.gfsp"), "{}", sum.corrupt[0]);
        assert!(
            sum.corrupt[0].contains(&format!("`{}`", e.label())),
            "scrub {:?} does not name {}",
            sum.corrupt[0],
            e.label()
        );
        assert!(
            store.load_partition_with(1, &all).is_ok(),
            "flip in {}: untouched partition must still load",
            e.label()
        );
        flipped += 1;
    }
    assert!(flipped >= 9, "only {flipped} sections exercised");

    // Directory flips are structural: the load fails and verify blames
    // the file's directory, before any body offset is trusted.
    for off in [packed::PRELUDE_LEN, packed::PRELUDE_LEN + 9] {
        let mut bad = clean.clone();
        bad[off] ^= 0x55;
        std::fs::write(&victim, &bad).unwrap();
        assert!(store.load_partition_with(0, &all).is_err());
        let sum = store.scrub().unwrap();
        assert_eq!(sum.corrupt.len(), 1, "{:?}", sum.corrupt);
        assert!(sum.corrupt[0].contains("host0/partition.gfsp"));
        assert!(sum.corrupt[0].contains("directory"), "{}", sum.corrupt[0]);
        assert!(store.load_partition_with(1, &all).is_ok());
    }

    // So is a rotted kind byte — the one prelude byte that says what
    // the file *is*.
    let mut bad = clean.clone();
    bad[5] ^= 0x01;
    std::fs::write(&victim, &bad).unwrap();
    assert!(store.load_partition_with(0, &all).is_err());
    let sum = store.scrub().unwrap();
    assert_eq!(sum.corrupt.len(), 1, "{:?}", sum.corrupt);
    assert!(sum.corrupt[0].contains("kind"), "{}", sum.corrupt[0]);

    // Restored, everything is clean again.
    std::fs::write(&victim, &clean).unwrap();
    assert!(store.scrub().unwrap().is_clean());
    assert!(store.load_all_with(&all).is_ok());
}

#[test]
fn packed_projected_bytes_match_directory_and_beat_v2() {
    // The byte-accounting contract of the packed loader: under
    // `AttrProjection::Only`, `LoadStats.bytes` equals the *sum of the
    // directory-listed lengths* of exactly the sections read (topology
    // + the projected columns), and is strictly below what the v2
    // per-file layout reads for the same projection (which pays
    // per-file headers, section tables, and attribute meta sections).
    let g = gen::road(12, 0.9, 0.02, 19);
    let p = MultilevelPartitioner::default().partition(&g, 2);
    let attrs = 10usize;

    let root2 = tmp("bytes_v2", 0);
    let (store2, dg) =
        Store::create_with_format(&root2, "g", &g, &p, SliceFormat::V2).unwrap();
    let root3 = tmp("bytes_v3", 0);
    let (store3, _) =
        Store::create_with_format(&root3, "g", &g, &p, SliceFormat::V3Packed).unwrap();
    let mut items = Vec::new();
    for sg in dg.subgraphs() {
        let vals: Vec<f32> = sg.vertices.iter().map(|&v| v as f32).collect();
        for a in 0..attrs {
            items.push((sg.id, format!("attr{a}"), vals.clone()));
        }
    }
    store2.write_attributes(&items).unwrap();
    store3.write_attributes(&items).unwrap();

    let only = LoadOptions {
        attributes: AttrProjection::Only(vec!["attr3".into()]),
        ..Default::default()
    };
    let (_, attrs3, st3) = store3.load_all_with(&only).unwrap();
    let (_, attrs2, st2) = store2.load_all_with(&only).unwrap();

    // Exact accounting, recomputed independently from the directories.
    let mut want_bytes = 0u64;
    for pid in 0..2u32 {
        let bytes = std::fs::read(
            root3.join(format!("host{pid}")).join(packed::PARTITION_FILE),
        )
        .unwrap();
        for e in &packed::parse(&bytes).unwrap().entries {
            if e.name.is_empty() || e.name == "attr3" {
                want_bytes += e.len;
            }
        }
    }
    assert_eq!(st3.bytes, want_bytes);
    // Strictly fewer bytes than v2's projected load of the same data…
    assert!(
        st3.bytes < st2.bytes,
        "v3 projected {} B must be < v2 projected {} B",
        st3.bytes,
        st2.bytes
    );
    // …for identical answers.
    assert_eq!(attrs3, attrs2);

    // The full v3 load reads every directory-listed byte, no more.
    let all = LoadOptions { attributes: AttrProjection::All, ..Default::default() };
    let (_, _, st3_full) = store3.load_all_with(&all).unwrap();
    let mut want_full = 0u64;
    for pid in 0..2u32 {
        let bytes = std::fs::read(
            root3.join(format!("host{pid}")).join(packed::PARTITION_FILE),
        )
        .unwrap();
        want_full += packed::parse(&bytes).unwrap().body_bytes();
    }
    assert_eq!(st3_full.bytes, want_full);
    assert!(st3.bytes < st3_full.bytes);
}

#[test]
fn slice_bytes_scale_with_subgraph_size() {
    // GoFS co-design: per-slice cost tracks topology size, so loading a
    // single attribute/topology slice touches only the needed bytes.
    let g = gen::road(30, 0.95, 0.01, 5);
    let parts = MultilevelPartitioner::default().partition(&g, 2);
    let root = tmp("scale", 0);
    let (store, dg) = Store::create(&root, "g", &g, &parts).unwrap();
    let (_, stats) = store.load_all().unwrap();
    // Compact codec: under ~12 bytes per vertex+edge at this density.
    let entities = g.num_vertices() + g.num_edges() * 2;
    assert!(
        stats.bytes < (entities * 12) as u64,
        "bytes={} entities={}",
        stats.bytes,
        entities
    );
    let _ = dg;
}
