//! GoFS store round-trips under randomized graphs/partitionings, and the
//! paper's structural invariants hold after a disk round-trip.

use std::path::PathBuf;

use goffish::gofs::{subgraph::discover, Store};
use goffish::graph::{gen, props, Graph};
use goffish::partition::{
    HashPartitioner, MultilevelPartitioner, Partitioner, RangePartitioner,
};
use goffish::util::rng::Rng;

fn tmp(name: &str, case: usize) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("goffish_gofs_rt")
        .join(format!("{name}_{case}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn random_graph(rng: &mut Rng) -> Graph {
    match rng.index(3) {
        0 => gen::road(6 + rng.index(12), 0.8 + rng.f64() * 0.19, 0.03, rng.next_u64()),
        1 => gen::social(80 + rng.index(200), 2 + rng.index(3), rng.f64() * 0.15, rng.next_u64()),
        _ => gen::erdos_renyi(40 + rng.index(100), 0.03, rng.chance(0.5), rng.next_u64()),
    }
}

#[test]
fn randomized_store_roundtrip_preserves_structure() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..10 {
        let weighted = rng.chance(0.5);
        let g0 = random_graph(&mut rng);
        let g = if weighted {
            gen::with_random_weights(&g0, 0.1, 9.9, rng.next_u64())
        } else {
            g0
        };
        let k = 2 + rng.index(4);
        let parts: Box<dyn Partitioner> = match rng.index(3) {
            0 => Box::new(HashPartitioner::new(rng.next_u64())),
            1 => Box::new(RangePartitioner),
            _ => Box::new(MultilevelPartitioner::new(rng.next_u64())),
        };
        let p = parts.partition(&g, k);
        let root = tmp("rand", case);
        let (store, dg) = Store::create(&root, "g", &g, &p).unwrap();
        let (dg2, stats) = store.load_all().unwrap();

        // Invariant 1: vertex partition-of-partitions.
        let mut seen = vec![0u32; g.num_vertices()];
        for sg in dg2.subgraphs() {
            for &v in &sg.vertices {
                seen[v as usize] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "case {case}: vertex coverage");

        // Invariant 2: edge conservation (local + remote_out = all).
        let local_edges: usize = dg2.subgraphs().map(|s| s.local.num_edges()).sum();
        let remote_edges: usize = dg2.subgraphs().map(|s| s.remote_out.len()).sum();
        assert_eq!(local_edges + remote_edges, g.num_edges(), "case {case}: edges");

        // Invariant 3: remote refs resolve to the correct sub-graph.
        for sg in dg2.subgraphs() {
            for r in &sg.remote_out {
                let target = &dg2.partitions[r.partition as usize][r.subgraph as usize];
                assert!(
                    target.local_id(r.target_global).is_some(),
                    "case {case}: remote ref {} not in {}",
                    r.target_global,
                    target.id
                );
            }
        }

        // Invariant 4: sub-graph count bounded by WCC structure: at least
        // the number of WCCs overall, at most the vertex count.
        assert!(dg2.num_subgraphs() >= props::wcc_count(&g));
        assert!(dg2.num_subgraphs() <= g.num_vertices());

        // Invariant 5: byte accounting matches files on disk.
        assert_eq!(stats.files as usize, dg.num_subgraphs());
        assert!(stats.bytes > 0);
    }
}

#[test]
fn slice_bytes_scale_with_subgraph_size() {
    // GoFS co-design: per-slice cost tracks topology size, so loading a
    // single attribute/topology slice touches only the needed bytes.
    let g = gen::road(30, 0.95, 0.01, 5);
    let parts = MultilevelPartitioner::default().partition(&g, 2);
    let root = tmp("scale", 0);
    let (store, dg) = Store::create(&root, "g", &g, &parts).unwrap();
    let (_, stats) = store.load_all().unwrap();
    // Compact codec: under ~12 bytes per vertex+edge at this density.
    let entities = g.num_vertices() + g.num_edges() * 2;
    assert!(
        stats.bytes < (entities * 12) as u64,
        "bytes={} entities={}",
        stats.bytes,
        entities
    );
    let _ = dg;
}
