//! Gopher-vs-Pregel result parity on randomized graphs: both engines
//! must compute identical answers for every algorithm (the paper's
//! comparison is only meaningful because the *answers* agree).

use std::collections::BTreeMap;

use goffish::algos::bfs::{BfsSg, BfsVx};
use goffish::algos::cc::{CcSg, CcVx};
use goffish::algos::pagerank::{PageRankSg, PageRankVx, RankKernel};
use goffish::algos::sssp::{SsspSg, SsspVx};
use goffish::algos::{gather_subgraph_values, gather_vertex_values};
use goffish::gofs::subgraph::discover;
use goffish::gopher::{run, GopherConfig};
use goffish::graph::gen;
use goffish::graph::Graph;
use goffish::partition::{HashPartitioner, MultilevelPartitioner, Partitioner};
use goffish::pregel::{run_vertex, PregelConfig};
use goffish::util::rng::Rng;

fn random_graph(rng: &mut Rng) -> Graph {
    match rng.index(4) {
        0 => gen::road(8 + rng.index(10), 0.85 + rng.f64() * 0.14, 0.02, rng.next_u64()),
        1 => gen::social(100 + rng.index(300), 2 + rng.index(4), rng.f64() * 0.1, rng.next_u64()),
        2 => gen::trace(100 + rng.index(400), 10 + rng.index(20), rng.f64() * 0.4, rng.next_u64()),
        _ => gen::erdos_renyi(50 + rng.index(150), 0.02, rng.chance(0.5), rng.next_u64()),
    }
}

#[test]
fn cc_parity_randomized() {
    let mut rng = Rng::new(2024);
    for case in 0..8 {
        let g = random_graph(&mut rng);
        let k = 2 + rng.index(3);
        let parts = MultilevelPartitioner::new(case).partition(&g, k);
        let dg = discover(&g, &parts).unwrap();
        let sg = gather_subgraph_values(
            &dg,
            &run(&dg, &CcSg, &GopherConfig::default()).unwrap().states,
        );
        let vx = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, k),
            &CcVx,
            &PregelConfig::default(),
        )
        .unwrap();
        assert_eq!(sg, vx.values, "case {case}: CC labels diverge");
    }
}

#[test]
fn bfs_parity_randomized() {
    let mut rng = Rng::new(777);
    for case in 0..8 {
        let g = random_graph(&mut rng);
        let k = 2 + rng.index(3);
        let source = rng.index(g.num_vertices()) as u32;
        let parts = MultilevelPartitioner::new(case).partition(&g, k);
        let dg = discover(&g, &parts).unwrap();
        let sg = gather_vertex_values(
            &dg,
            &run(&dg, &BfsSg { source }, &GopherConfig::default())
                .unwrap()
                .states,
        );
        let vx = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, k),
            &BfsVx { source },
            &PregelConfig::default(),
        )
        .unwrap();
        assert_eq!(sg, vx.values, "case {case}: BFS levels diverge (src {source})");
    }
}

#[test]
fn sssp_parity_randomized() {
    let mut rng = Rng::new(31337);
    for case in 0..6 {
        let g0 = random_graph(&mut rng);
        let g = gen::with_random_weights(&g0, 0.5, 9.5, rng.next_u64());
        let k = 2 + rng.index(3);
        let source = rng.index(g.num_vertices()) as u32;
        let parts = MultilevelPartitioner::new(case).partition(&g, k);
        let dg = discover(&g, &parts).unwrap();
        let res = run(&dg, &SsspSg { source }, &GopherConfig::default()).unwrap();
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.dist)).collect();
        let sg = gather_vertex_values(&dg, &states);
        let vx = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, k),
            &SsspVx { source },
            &PregelConfig::default(),
        )
        .unwrap();
        for (v, (&a, &b)) in sg.iter().zip(&vx.values).enumerate() {
            let ok = (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3;
            assert!(ok, "case {case} vertex {v}: sg={a} vx={b}");
        }
    }
}

#[test]
fn pagerank_parity_randomized() {
    let mut rng = Rng::new(555);
    for case in 0..5 {
        let g = random_graph(&mut rng);
        let k = 2 + rng.index(3);
        let parts = MultilevelPartitioner::new(case).partition(&g, k);
        let dg = discover(&g, &parts).unwrap();
        let prog = PageRankSg { supersteps: 12, kernel: RankKernel::Scalar };
        let res = run(&dg, &prog, &GopherConfig::default()).unwrap();
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.ranks)).collect();
        let sg = gather_vertex_values(&dg, &states);
        let vx = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, k),
            &PageRankVx { supersteps: 12 },
            &PregelConfig::default(),
        )
        .unwrap();
        for (v, (&a, &b)) in sg.iter().zip(&vx.values).enumerate() {
            assert!(
                (a - b).abs() < 1e-5 + 1e-3 * b.abs(),
                "case {case} vertex {v}: sg={a} vx={b}"
            );
        }
    }
}
