//! Gopher-vs-Pregel result parity on randomized graphs: both engines
//! must compute identical answers for every algorithm (the paper's
//! comparison is only meaningful because the *answers* agree).
//!
//! The combiner tests double as the coordinator-layer acceptance: with
//! combiners enabled on both engines the answers still agree, and the
//! combiner-enabled Gopher runs ship strictly fewer bytes than
//! combiner-disabled ones (asserted on `JobMetrics`).

use std::collections::BTreeMap;

use goffish::algos::bfs::{BfsSg, BfsVx};
use goffish::algos::cc::{CcSg, CcVx};
use goffish::algos::pagerank::{PageRankSg, PageRankVx, RankKernel};
use goffish::algos::sssp::{SsspSg, SsspVx};
use goffish::algos::{gather_subgraph_values, gather_vertex_values};
use goffish::gofs::subgraph::discover;
use goffish::gopher::{run, GopherConfig};
use goffish::graph::gen;
use goffish::graph::Graph;
use goffish::job::{EngineKind, Job, JobSource};
use goffish::partition::{HashPartitioner, MultilevelPartitioner, Partitioner};
use goffish::pregel::{run_vertex, PregelConfig};
use goffish::testing::fixtures;
use goffish::util::rng::Rng;

fn random_graph(rng: &mut Rng) -> Graph {
    fixtures::random_graph(rng)
}

#[test]
fn cc_parity_randomized() {
    let mut rng = Rng::new(2024);
    for case in 0..8 {
        let g = random_graph(&mut rng);
        let k = 2 + rng.index(3);
        let parts = MultilevelPartitioner::new(case).partition(&g, k);
        let dg = discover(&g, &parts).unwrap();
        let sg = gather_subgraph_values(
            &dg,
            &run(&dg, &CcSg, &GopherConfig::default()).unwrap().states,
        );
        let vx = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, k),
            &CcVx,
            &PregelConfig::default(),
        )
        .unwrap();
        assert_eq!(sg, vx.values, "case {case}: CC labels diverge");
    }
}

#[test]
fn bfs_parity_randomized() {
    let mut rng = Rng::new(777);
    for case in 0..8 {
        let g = random_graph(&mut rng);
        let k = 2 + rng.index(3);
        let source = rng.index(g.num_vertices()) as u32;
        let parts = MultilevelPartitioner::new(case).partition(&g, k);
        let dg = discover(&g, &parts).unwrap();
        let sg = gather_vertex_values(
            &dg,
            &run(&dg, &BfsSg { source }, &GopherConfig::default())
                .unwrap()
                .states,
        );
        let vx = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, k),
            &BfsVx { source },
            &PregelConfig::default(),
        )
        .unwrap();
        assert_eq!(sg, vx.values, "case {case}: BFS levels diverge (src {source})");
    }
}

#[test]
fn sssp_parity_randomized() {
    let mut rng = Rng::new(31337);
    for case in 0..6 {
        let g0 = random_graph(&mut rng);
        let g = gen::with_random_weights(&g0, 0.5, 9.5, rng.next_u64());
        let k = 2 + rng.index(3);
        let source = rng.index(g.num_vertices()) as u32;
        let parts = MultilevelPartitioner::new(case).partition(&g, k);
        let dg = discover(&g, &parts).unwrap();
        let res = run(&dg, &SsspSg { source }, &GopherConfig::default()).unwrap();
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.dist)).collect();
        let sg = gather_vertex_values(&dg, &states);
        let vx = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, k),
            &SsspVx { source },
            &PregelConfig::default(),
        )
        .unwrap();
        for (v, (&a, &b)) in sg.iter().zip(&vx.values).enumerate() {
            let ok = (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3;
            assert!(ok, "case {case} vertex {v}: sg={a} vx={b}");
        }
    }
}

#[test]
fn cc_combiner_parity_and_byte_reduction() {
    // Hash-scattered chain: many tiny sub-graphs per worker, so several
    // same-worker sub-graphs flood labels toward one remote mailbox —
    // exactly what the combiner folds.
    let g = gen::chain(60);
    let parts = HashPartitioner::default().partition(&g, 3);
    let dg = discover(&g, &parts).unwrap();

    let with = run(&dg, &CcSg, &GopherConfig::default()).unwrap();
    let without_cfg = GopherConfig { combiners: false, ..Default::default() };
    let without = run(&dg, &CcSg, &without_cfg).unwrap();

    // Combiners enabled on BOTH engines: answers agree everywhere.
    let sg_labels = gather_subgraph_values(&dg, &with.states);
    let vx = run_vertex(&g, &parts, &CcVx, &PregelConfig::default()).unwrap();
    assert_eq!(sg_labels, vx.values, "gopher+combiner vs pregel+combiner");
    assert_eq!(sg_labels, gather_subgraph_values(&dg, &without.states));

    // And the combiner strictly reduces bytes on the wire.
    assert!(with.metrics.total_combined() > 0, "combiner never fired");
    assert_eq!(without.metrics.total_combined(), 0);
    assert!(
        with.metrics.total_bytes() < without.metrics.total_bytes(),
        "combined CC bytes {} must be < uncombined {}",
        with.metrics.total_bytes(),
        without.metrics.total_bytes()
    );
    // The pregel baseline combines too (its own fold path).
    assert!(vx.metrics.total_combined() > 0);
}

#[test]
fn sssp_combiner_parity_and_byte_reduction() {
    let g0 = gen::social(400, 5, 0.0, 77);
    let g = gen::with_random_weights(&g0, 0.5, 4.5, 78);
    let k = 3;
    let parts = HashPartitioner::default().partition(&g, k);
    let dg = discover(&g, &parts).unwrap();
    let source = (0..g.num_vertices() as u32)
        .max_by_key(|&v| g.out_degree(v))
        .unwrap_or(0);

    let with = run(&dg, &SsspSg { source }, &GopherConfig::default()).unwrap();
    let without_cfg = GopherConfig { combiners: false, ..Default::default() };
    let without = run(&dg, &SsspSg { source }, &without_cfg).unwrap();

    let dist = |res: goffish::gopher::RunResult<goffish::algos::sssp::SsspState>| {
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.dist)).collect();
        gather_vertex_values(&dg, &states)
    };
    let with_bytes = with.metrics.total_bytes();
    let with_combined = with.metrics.total_combined();
    let without_bytes = without.metrics.total_bytes();
    let a = dist(with);
    let b = dist(without);
    for (v, (&x, &y)) in a.iter().zip(&b).enumerate() {
        let ok = (x.is_infinite() && y.is_infinite()) || (x - y).abs() < 1e-4;
        assert!(ok, "vertex {v}: with-combiner {x} vs without {y}");
    }
    // Combiner-enabled vs pregel baseline (also combiner-enabled).
    let vx = run_vertex(&g, &parts, &SsspVx { source }, &PregelConfig::default()).unwrap();
    for (v, (&x, &y)) in a.iter().zip(&vx.values).enumerate() {
        let ok = (x.is_infinite() && y.is_infinite()) || (x - y).abs() < 1e-3;
        assert!(ok, "vertex {v}: gopher {x} vs pregel {y}");
    }

    assert!(with_combined > 0, "combiner never fired");
    assert!(
        with_bytes < without_bytes,
        "combined SSSP bytes {with_bytes} must be < uncombined {without_bytes}"
    );
}

/// Unified-output parity: the new `JobOutput::values` surface must agree
/// across engines per vertex — the old parity tests compared engine-
/// native result shapes; this one exercises the emit→values path both
/// engines now share.
#[test]
fn job_output_values_agree_across_engines() {
    let g0 = gen::social(300, 4, 0.02, 41);
    let g = gen::with_random_weights(&g0, 0.5, 4.5, 43);
    let part = MultilevelPartitioner::default();
    let run_job = |algo: &str, engine: EngineKind| {
        Job::builder()
            .algo(algo)
            .engine(engine)
            .supersteps(12)
            .source_vertex(0)
            .build()
            .unwrap()
            .run(JobSource::Graph { graph: &g, partitioner: &part, partitions: 3 })
            .unwrap()
    };
    for algo in ["cc", "sssp", "pagerank"] {
        let a = run_job(algo, EngineKind::Gopher).values;
        let b = run_job(algo, EngineKind::Vertex).values;
        assert_eq!(a.len(), g.num_vertices(), "{algo}: gopher emit coverage");
        assert_eq!(b.len(), g.num_vertices(), "{algo}: vertex emit coverage");
        for (&(va, xa), &(vb, xb)) in a.iter().zip(&b) {
            assert_eq!(va, vb, "{algo}: vertex id order diverges");
            let ok = if algo == "pagerank" {
                (xa - xb).abs() < 1e-5 + 1e-3 * xb.abs()
            } else {
                (xa.is_infinite() && xb.is_infinite()) || (xa - xb).abs() < 1e-3
            };
            assert!(ok, "{algo} vertex {va}: gopher={xa} vertex-engine={xb}");
        }
    }
}

#[test]
fn dense_and_sorted_lookup_parity_on_gapped_ids() {
    // Hash-scattering over 6 hosts gives every sub-graph a strided
    // (u32-gapped) vertex set — span ≈ n while len ≈ n/6 — so
    // `VertexIndex::build` takes the sorted fallback even with
    // `dense_index: true`, while the multilevel partitioning keeps
    // contiguous runs that build dense tables. Both engines, both knob
    // settings, both partitionings: identical answers everywhere.
    use goffish::util::index::VertexIndex;
    let g = gen::social(600, 5, 0.02, 99);
    let k = 6;
    let source = (0..g.num_vertices() as u32)
        .max_by_key(|&v| g.out_degree(v))
        .unwrap_or(0);
    for (label, parts) in [
        ("hash", HashPartitioner::default().partition(&g, k)),
        ("multilevel", MultilevelPartitioner::default().partition(&g, k)),
    ] {
        let dg = discover(&g, &parts).unwrap();
        if label == "hash" {
            // Pin the premise: the scatter must actually exercise the
            // sparse fallback somewhere, or this test proves nothing.
            assert!(
                dg.subgraphs().any(|sg| matches!(
                    VertexIndex::build(&sg.vertices),
                    VertexIndex::Sorted(_)
                )),
                "hash scatter produced no u32-gapped sub-graph"
            );
        }
        let sorted_sg = GopherConfig { dense_index: false, ..Default::default() };
        let sorted_vx = PregelConfig { dense_index: false, ..Default::default() };

        let cc_dense = gather_subgraph_values(
            &dg,
            &run(&dg, &CcSg, &GopherConfig::default()).unwrap().states,
        );
        let cc_sorted =
            gather_subgraph_values(&dg, &run(&dg, &CcSg, &sorted_sg).unwrap().states);
        assert_eq!(cc_dense, cc_sorted, "{label}: gopher CC dense vs sorted");
        let cc_vx_dense = run_vertex(&g, &parts, &CcVx, &PregelConfig::default()).unwrap();
        let cc_vx_sorted = run_vertex(&g, &parts, &CcVx, &sorted_vx).unwrap();
        assert_eq!(cc_vx_dense.values, cc_vx_sorted.values, "{label}: pregel CC");
        assert_eq!(cc_dense, cc_vx_dense.values, "{label}: CC engines diverge");

        let bfs_dense = gather_vertex_values(
            &dg,
            &run(&dg, &BfsSg { source }, &GopherConfig::default())
                .unwrap()
                .states,
        );
        let bfs_sorted = gather_vertex_values(
            &dg,
            &run(&dg, &BfsSg { source }, &sorted_sg).unwrap().states,
        );
        assert_eq!(bfs_dense, bfs_sorted, "{label}: gopher BFS dense vs sorted");
        let bfs_vx_dense =
            run_vertex(&g, &parts, &BfsVx { source }, &PregelConfig::default()).unwrap();
        let bfs_vx_sorted = run_vertex(&g, &parts, &BfsVx { source }, &sorted_vx).unwrap();
        assert_eq!(bfs_vx_dense.values, bfs_vx_sorted.values, "{label}: pregel BFS");
        assert_eq!(bfs_dense, bfs_vx_dense.values, "{label}: BFS engines diverge");
    }
}

#[test]
fn pagerank_parity_randomized() {
    let mut rng = Rng::new(555);
    for case in 0..5 {
        let g = random_graph(&mut rng);
        let k = 2 + rng.index(3);
        let parts = MultilevelPartitioner::new(case).partition(&g, k);
        let dg = discover(&g, &parts).unwrap();
        let prog = PageRankSg { supersteps: 12, kernel: RankKernel::Scalar, epsilon: None };
        let res = run(&dg, &prog, &GopherConfig::default()).unwrap();
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.ranks)).collect();
        let sg = gather_vertex_values(&dg, &states);
        let vx = run_vertex(
            &g,
            &HashPartitioner::default().partition(&g, k),
            &PageRankVx { supersteps: 12 },
            &PregelConfig::default(),
        )
        .unwrap();
        for (v, (&a, &b)) in sg.iter().zip(&vx.values).enumerate() {
            assert!(
                (a - b).abs() < 1e-5 + 1e-3 * b.abs(),
                "case {case} vertex {v}: sg={a} vx={b}"
            );
        }
    }
}
