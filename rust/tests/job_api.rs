//! Unified job layer, end to end: builder validation, every source
//! kind, full registry coverage, and the coordinator on the vertex
//! engine (the labelprop-style aggregator termination acceptance test).

use goffish::algos::labelprop::{LabelPropVx, AGG_CHANGES};
use goffish::gofs::{subgraph::discover, SliceFormat, Store};
use goffish::graph::{gen, Graph};
use goffish::job::{EngineKind, Job, JobError, JobSource};
use goffish::partition::{HashPartitioner, MultilevelPartitioner, Partitioner};
use goffish::pregel::{run_vertex, PregelConfig};

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("goffish_job_api")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn builder_validation_is_typed_and_build_time() {
    assert!(matches!(
        Job::builder().build().unwrap_err(),
        JobError::MissingAlgo
    ));
    assert!(matches!(
        Job::builder().algo("no-such-algo").build().unwrap_err(),
        JobError::UnknownAlgo { .. }
    ));
    assert!(matches!(
        Job::builder()
            .algo("blockrank")
            .engine(EngineKind::Vertex)
            .build()
            .unwrap_err(),
        JobError::UnsupportedEngine { .. }
    ));
    assert!(matches!(
        Job::builder()
            .algo("pagerank")
            .engine(EngineKind::Vertex)
            .epsilon(0.01)
            .build()
            .unwrap_err(),
        JobError::IncompatibleKnob { knob: "epsilon", .. }
    ));
    assert!(matches!(
        Job::builder()
            .algo("cc")
            .engine(EngineKind::Vertex)
            .combiners(false)
            .build()
            .unwrap_err(),
        JobError::IncompatibleKnob { knob: "combiners", .. }
    ));
    // The same description is valid on Gopher.
    assert!(Job::builder()
        .algo("pagerank")
        .epsilon(0.01)
        .combiners(false)
        .build()
        .is_ok());
}

#[test]
fn all_sources_agree_on_both_engines() {
    let g = gen::road(12, 0.9, 0.02, 19);
    let part = MultilevelPartitioner::default();
    let parts = part.partition(&g, 3);
    let dg = discover(&g, &parts).unwrap();
    let root = tmp("sources");
    let (store, _) = Store::create(&root, "t", &g, &parts).unwrap();

    let job = Job::builder().algo("cc").build().unwrap();
    let mem = job.run(JobSource::InMemory(&dg)).unwrap();
    let disk = job.run(JobSource::Store(&store)).unwrap();
    let graph_src = job
        .run(JobSource::Graph { graph: &g, partitioner: &part, partitions: 3 })
        .unwrap();
    assert_eq!(mem.values.len(), g.num_vertices());
    assert_eq!(mem.values, disk.values);
    assert_eq!(mem.values, graph_src.values);

    // The vertex engine reaches the same answer from every source
    // (store + in-memory go through gofs::reassemble).
    let vjob = Job::builder().algo("cc").engine(EngineKind::Vertex).build().unwrap();
    assert_eq!(mem.values, vjob.run(JobSource::Store(&store)).unwrap().values);
    assert_eq!(mem.values, vjob.run(JobSource::InMemory(&dg)).unwrap().values);
    assert_eq!(
        mem.values,
        vjob.run(JobSource::Graph { graph: &g, partitioner: &part, partitions: 3 })
            .unwrap()
            .values
    );
}

#[test]
fn store_formats_give_identical_job_output_on_both_engines() {
    // Acceptance for the packed store: the same graph written as
    // v1/v2/v3 must yield byte-identical JobOutput values through the
    // job layer, whichever engine runs it (Gopher loads data-locally,
    // the vertex baseline reassembles — both paths cross the format
    // dispatch).
    let g = gen::road(10, 0.92, 0.02, 23);
    let parts = MultilevelPartitioner::default().partition(&g, 3);
    let mut baseline: Option<Vec<(u32, f64)>> = None;
    for fmt in [SliceFormat::V1, SliceFormat::V2, SliceFormat::V3Packed] {
        let root = tmp(&format!("fmt_parity_{fmt}"));
        let (store, _) = Store::create_with_format(&root, "g", &g, &parts, fmt).unwrap();
        for engine in [EngineKind::Gopher, EngineKind::Vertex] {
            let out = Job::builder()
                .algo("cc")
                .engine(engine)
                .build()
                .unwrap()
                .run(JobSource::Store(&store))
                .unwrap();
            match &baseline {
                None => baseline = Some(out.values),
                Some(want) => {
                    assert_eq!(&out.values, want, "{fmt}/{engine} diverges");
                }
            }
        }
    }
}

#[test]
fn every_registered_algo_runs_through_the_job_layer() {
    let g = gen::road(10, 0.9, 0.02, 7);
    let part = HashPartitioner::default();
    for entry in goffish::algos::registry::entries() {
        let out = Job::builder()
            .algo(entry.name)
            .supersteps(8)
            .build()
            .unwrap()
            .run(JobSource::Graph { graph: &g, partitioner: &part, partitions: 2 })
            .unwrap();
        assert_eq!(
            out.values.len(),
            g.num_vertices(),
            "{}: every vertex must be covered by emit",
            entry.name
        );
        assert!(out.metrics.num_supersteps() > 0, "{}", entry.name);
        // Vertex-id order, each vertex exactly once.
        for (i, &(v, _)) in out.values.iter().enumerate() {
            assert_eq!(v as usize, i, "{}", entry.name);
        }
    }
}

/// Two 5-cliques joined by one bridge edge (deterministic LP fixture).
fn two_cliques() -> Graph {
    let mut edges = Vec::new();
    for c in [0u32, 5] {
        for i in 0..5 {
            for j in (i + 1)..5 {
                edges.push((c + i, c + j));
            }
        }
    }
    edges.push((4, 5)); // bridge
    Graph::from_edges(10, &edges, None, false).unwrap()
}

/// Acceptance: a Pregel job can register + read a global aggregator —
/// labelprop-style termination on the vertex engine.
#[test]
fn pregel_job_registers_and_reads_global_aggregator() {
    let g = two_cliques();
    let parts = HashPartitioner::default().partition(&g, 3);
    let prog = LabelPropVx::default();
    let res = run_vertex(&g, &parts, &prog, &PregelConfig::default()).unwrap();
    let steps = res.metrics.num_supersteps();
    // Termination came from observing the folded global change count,
    // not from the round cap.
    assert!(steps < prog.max_rounds, "steps={steps}");
    let trace = res
        .metrics
        .aggregator(AGG_CHANGES)
        .expect("coordinator trace on the vertex engine");
    assert_eq!(trace.values.len(), steps);
    // Superstep 1 is the bootstrap round: every vertex counts once.
    assert_eq!(trace.values[0], g.num_vertices() as f64);
    // The fold every vertex observed before halting was zero.
    assert_eq!(trace.values[steps - 2], 0.0, "{:?}", trace.values);
    // Each clique settled on one label.
    assert!(res.values[0..5].iter().all(|&l| l == res.values[0]));
    assert!(res.values[5..10].iter().all(|&l| l == res.values[5]));

    // And through the unified surface the same run yields per-vertex
    // values plus the mirrored trace.
    let out = Job::builder()
        .algo("labelprop")
        .engine(EngineKind::Vertex)
        .supersteps(50)
        .build()
        .unwrap()
        .run(JobSource::Graph {
            graph: &g,
            partitioner: &HashPartitioner::default(),
            partitions: 3,
        })
        .unwrap();
    assert_eq!(out.values.len(), 10);
    assert!(out.aggregators.iter().any(|t| t.name == AGG_CHANGES));
}
