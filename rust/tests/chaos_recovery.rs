//! Chaos recovery drill (ROADMAP direction 5): pseudo-random kills
//! across a multi-job sequence on the TCP fabric, in both checkpoint
//! modes (sync and async, compressed and plain) and both recovery
//! scopes (global rollback and confined single-worker restart). Every
//! recovered `JobOutput` — values *and* aggregator traces — must be
//! byte-exact against the same job running uninterrupted; that is the
//! contract PR 4's deterministic replay makes testable.

use std::path::PathBuf;

use goffish::ckpt::{self, CheckpointMode};
use goffish::gofs::Store;
use goffish::gopher::FabricKind;
use goffish::graph::gen;
use goffish::job::{EngineKind, Job, JobBuilder, JobOutput, JobSource};
use goffish::partition::{MultilevelPartitioner, Partitioner};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("goffish_chaos_recovery")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn build_store(name: &str) -> Store {
    let g = gen::with_random_weights(&gen::road(12, 0.92, 0.02, 7), 1.0, 10.0, 8);
    let parts = MultilevelPartitioner::default().partition(&g, 3);
    let (store, _) = Store::create(&tmp(name), "chaos", &g, &parts).unwrap();
    store
}

/// Deterministic xorshift64* so the "random" kill schedule is stable
/// across runs — chaos we can re-run is chaos we can debug.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform-ish pick in `lo..=hi`.
    fn pick(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
}

fn base_job(algo: &str, engine: EngineKind) -> JobBuilder {
    Job::builder()
        .algo(algo)
        .engine(engine)
        .fabric(FabricKind::Tcp)
        .supersteps(8)
        .source_vertex(0)
}

fn assert_output_identical(a: &JobOutput, b: &JobOutput, label: &str) {
    assert_eq!(a.values, b.values, "{label}: values diverged");
    assert_eq!(
        a.aggregators.len(),
        b.aggregators.len(),
        "{label}: aggregator count diverged"
    );
    for (ta, tb) in a.aggregators.iter().zip(&b.aggregators) {
        assert_eq!(ta.name, tb.name, "{label}");
        assert_eq!(ta.values, tb.values, "{label}: trace {} diverged", ta.name);
    }
}

/// Run the whole chaos matrix for one algorithm/engine: every
/// (mode, recovery-scope) combination, each with a pseudo-random kill
/// point, against one uninterrupted baseline.
fn chaos_drill(store: &Store, algo: &str, engine: EngineKind, rng: &mut Rng) {
    let baseline = base_job(algo, engine)
        .build()
        .unwrap()
        .run(JobSource::Store(store))
        .unwrap();

    let scenarios = [
        (CheckpointMode::Sync, false),
        (CheckpointMode::Sync, true),
        (CheckpointMode::Async, false),
        (CheckpointMode::Async, true),
    ];
    for (mode, confined) in scenarios {
        // Random kill point: late enough that an epoch committed, early
        // enough that the job is still mid-flight (the 8-superstep jobs
        // here never quiesce before superstep 4).
        let kill_at = rng.pick(2, 4) as usize;
        let worker = rng.pick(0, 2) as u32;
        // Exercise compression on half the matrix.
        let compress = confined;
        let label =
            format!("{algo}/{engine:?}/{mode}/confined={confined}/kill {worker}@{kill_at}");
        assert!(
            baseline.metrics.num_supersteps() > kill_at,
            "{label}: drill needs a kill before natural termination"
        );
        let dir = tmp(&format!(
            "{algo}_{engine:?}_{mode}_{confined}_{kill_at}_{worker}"
        ));

        let err = base_job(algo, engine)
            .checkpoint_every(1)
            .checkpoint_dir(&dir)
            .checkpoint_mode(mode)
            .checkpoint_compress(compress)
            .kill_at(kill_at, worker)
            .build()
            .unwrap()
            .run(JobSource::Store(store))
            .expect_err("killed run must fail");
        assert!(
            format!("{err:#}").contains("injected worker failure"),
            "{label}: {err:#}"
        );
        // The aborted run recorded whom it lost — confined recovery
        // reads this marker to decide which worker to rebuild.
        assert_eq!(
            ckpt::read_failed_marker(&dir).unwrap(),
            Some(worker),
            "{label}: FAILED_WORKER marker"
        );

        let resumed = base_job(algo, engine)
            .resume_from(&dir)
            .confined_recovery(confined)
            .build()
            .unwrap()
            .run(JobSource::Store(store))
            .unwrap();
        assert_output_identical(&baseline, &resumed, &label);
    }
}

#[test]
fn chaos_recovery_gopher_tcp() {
    let store = build_store("gopher");
    let mut rng = Rng(0x9E3779B97F4A7C15);
    // Two jobs back to back on the same store — the multi-job shape:
    // a float-summing fixed-length job and an aggregator-terminated one.
    chaos_drill(&store, "pagerank", EngineKind::Gopher, &mut rng);
    chaos_drill(&store, "cc", EngineKind::Gopher, &mut rng);
}

#[test]
fn chaos_recovery_vertex_tcp() {
    let store = build_store("vertex");
    let mut rng = Rng(0xD1B54A32D192ED03);
    chaos_drill(&store, "pagerank", EngineKind::Vertex, &mut rng);
    chaos_drill(&store, "cc", EngineKind::Vertex, &mut rng);
}

#[test]
fn confined_recovery_without_a_marker_is_a_typed_refusal() {
    // A directory whose run completed (or predates failure markers)
    // cannot answer a confined resume: the builder resolves the epoch,
    // but the run fails loudly asking for the marker instead of
    // silently doing a global rollback.
    let store = build_store("nomarker");
    let dir = tmp("nomarker_ckpt");
    base_job("cc", EngineKind::Gopher)
        .checkpoint_every(1)
        .checkpoint_dir(&dir)
        .build()
        .unwrap()
        .run(JobSource::Store(&store))
        .unwrap();
    assert_eq!(ckpt::read_failed_marker(&dir).unwrap(), None);
    let err = base_job("cc", EngineKind::Gopher)
        .resume_from(&dir)
        .confined_recovery(true)
        .build()
        .unwrap()
        .run(JobSource::Store(&store))
        .expect_err("confined resume without a marker must fail");
    assert!(format!("{err:#}").contains("FAILED_WORKER"), "{err:#}");
}
