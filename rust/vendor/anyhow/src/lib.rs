//! Offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored crate
//! implements exactly the subset GoFFish uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] / [`ensure!`] macros, and the [`Context`]
//! extension trait on `Result` and `Option`. Context chains render the
//! way callers expect from real anyhow: `{}` prints the outermost
//! message, `{:#}` joins the whole chain with `: `, and `{:?}` prints a
//! `Caused by:` list.

use std::fmt;

/// A dynamic error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Iterate the context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    fn wrap(mut self, context: String) -> Error {
        self.chain.insert(0, context);
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that keeps this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` alias (error type defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and turn `None` into an error).
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Error::from(io_err()).wrap("loading slice".to_string());
        assert_eq!(format!("{e}"), "loading slice");
        assert_eq!(format!("{e:#}"), "loading slice: disk on fire");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: disk on fire");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Err(anyhow!("fell through with {}", x))
        }
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(1).unwrap_err().to_string(), "fell through with 1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
