//! Gated offline stub of the `xla` PJRT bindings.
//!
//! The real crate links libpjrt, which is not present in the offline
//! build image. This stand-in mirrors the API surface that
//! `goffish::runtime::engine` uses so the runtime layer compiles
//! unchanged; every entry point that would touch PJRT returns a clear
//! error, and [`PjRtClient::cpu`] fails fast — so `XlaEngine::load`
//! reports "backend unavailable" instead of crashing, and all algorithm
//! paths fall back to the scalar kernels (`RankKernel::Scalar`).
//!
//! Swapping the real bindings back in is a one-line change in the root
//! `Cargo.toml` (point the `xla` dependency at crates.io or a checkout).

use std::fmt;

/// Error type matching the shape `goffish::runtime` formats with `{e}`.
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT backend not available (offline xla stub; \
         vendor the real xla crate to enable accelerator kernels)"
    )))
}

/// PJRT client handle. The stub's constructor always fails, which gates
/// every downstream call site at engine start-up.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Host-side literal (dense tensor) handle.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer produced by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_is_gated() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline xla stub"));
    }
}
