//! Micro-benchmarks of the L3 hot paths — the §Perf baseline numbers.
//!
//! * codec encode/decode throughput (GoFS slice + message wire format)
//! * sub-graph discovery throughput
//! * superstep overhead: an empty-compute Gopher superstep (barrier +
//!   routing + drain, no work) — the fixed cost every superstep pays
//! * message routing throughput (PageRank superstep on LJ analog)
//! * thread-pool dispatch overhead

mod common;

use goffish::algos::pagerank::{PageRankSg, RankKernel};
use goffish::bench::{fmt_secs, measure, JsonEmitter, Table};
use goffish::gofs::subgraph::discover;
use goffish::gofs::Subgraph;
use goffish::gopher::{
    run, GopherConfig, IncomingMessage, SubgraphContext, SubgraphProgram,
};
use goffish::partition::{MultilevelPartitioner, Partitioner};
use goffish::util::codec::{Decoder, Encoder};
use goffish::util::pool;

/// `GOFFISH_BENCH_QUICK=1` shrinks warmups/reps to CI-smoke size — the
/// harness still exercises every case, it just stops measuring
/// carefully (the CI job only guards against perf-harness rot).
fn quick() -> bool {
    matches!(
        std::env::var("GOFFISH_BENCH_QUICK").as_deref(),
        Ok(v) if !v.is_empty() && v != "0"
    )
}

fn reps(warmup: usize, reps: usize) -> (usize, usize) {
    if quick() {
        (0, 1)
    } else {
        (warmup, reps)
    }
}

fn main() {
    let mut json = JsonEmitter::from_env("micro", common::scale());
    let mut t = Table::new("L3 micro-benchmarks", &["case", "median", "note"]);

    // Codec throughput.
    let vals: Vec<u64> = (0..100_000u64).map(|i| i.wrapping_mul(0x9E3779B9)).collect();
    let (w, r) = reps(2, 10);
    let m = measure(w, r, || {
        let mut e = Encoder::with_capacity(vals.len() * 5);
        for &v in &vals {
            e.put_varint(v);
        }
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        for _ in 0..vals.len() {
            let _ = d.get_varint().unwrap();
        }
    });
    t.row(&[
        "codec 100k varints rt".into(),
        fmt_secs(m.median),
        format!("{:.0} Mops/s", 0.2 / m.median),
    ]);
    json.emit("-", "codec_100k_varints_seconds", m.median);

    // Discovery throughput.
    let g = goffish::graph::gen::rn_analog(common::scale(), 11);
    let parts = MultilevelPartitioner::default().partition(&g, common::K);
    let (w, r) = reps(1, 5);
    let m = measure(w, r, || {
        let dg = discover(&g, &parts).unwrap();
        assert!(dg.num_subgraphs() > 0);
    });
    t.row(&[
        format!("discovery RN ({}v)", g.num_vertices()),
        fmt_secs(m.median),
        format!("{:.1} Mv/s", g.num_vertices() as f64 / m.median / 1e6),
    ]);
    json.emit("RN", "discovery_seconds", m.median);

    // Empty superstep overhead.
    struct NSteps(usize);
    impl SubgraphProgram for NSteps {
        type Msg = ();
        type State = ();
        fn init(&self, _sg: &Subgraph) {}
        fn compute(
            &self,
            _s: &mut (),
            _sg: &Subgraph,
            ctx: &mut SubgraphContext<'_, ()>,
            _m: &[IncomingMessage<()>],
        ) {
            if ctx.superstep() >= self.0 {
                ctx.vote_to_halt();
            }
        }
    }
    let dg = discover(&g, &parts).unwrap();
    let steps = if quick() { 5 } else { 50 };
    let (w, r) = reps(1, 5);
    let m = measure(w, r, || {
        let res = run(&dg, &NSteps(steps), &GopherConfig::default()).unwrap();
        assert_eq!(res.metrics.num_supersteps(), steps);
    });
    t.row(&[
        format!("empty superstep x{steps} (k={})", common::K),
        fmt_secs(m.median),
        format!("{} per superstep", fmt_secs(m.median / steps as f64)),
    ]);
    json.emit("RN", "empty_superstep_seconds", m.median / steps as f64);

    // PageRank superstep (message routing + compute on LJ analog).
    let lj = goffish::graph::gen::lj_analog(common::scale(), 33);
    let ljp = MultilevelPartitioner::default().partition(&lj, common::K);
    let ljdg = discover(&lj, &ljp).unwrap();
    let (w, r) = reps(1, 3);
    let m = measure(w, r, || {
        let prog = PageRankSg { supersteps: 5, kernel: RankKernel::Scalar, epsilon: None };
        run(&ljdg, &prog, &GopherConfig::default()).unwrap();
    });
    t.row(&[
        format!("pagerank 5 ss LJ ({}e)", lj.num_edges()),
        fmt_secs(m.median),
        format!("{} per superstep", fmt_secs(m.median / 5.0)),
    ]);
    json.emit("LJ", "pagerank_superstep_seconds", m.median / 5.0);
    let plain_per_ss = m.median / 5.0;

    // Superstep throughput, dense vs sorted vertex lookup: the plain
    // run above already uses the dense u32 index (`GopherConfig`
    // default); re-run with `dense_index: false` to price the
    // sorted-fallback binary search the dense remap replaced.
    let sorted_cfg = GopherConfig { dense_index: false, ..Default::default() };
    let (w, r) = reps(1, 3);
    let m_sorted = measure(w, r, || {
        let prog = PageRankSg { supersteps: 5, kernel: RankKernel::Scalar, epsilon: None };
        run(&ljdg, &prog, &sorted_cfg).unwrap();
    });
    let dense_eps = lj.num_edges() as f64 / plain_per_ss;
    let sorted_eps = lj.num_edges() as f64 / (m_sorted.median / 5.0);
    t.row(&[
        "pagerank 5 ss LJ, sorted lookup".into(),
        fmt_secs(m_sorted.median),
        format!("{:.2} vs {:.2} Me/ss-s dense", sorted_eps / 1e6, dense_eps / 1e6),
    ]);
    json.emit("LJ", "superstep_throughput_dense_eps", dense_eps);
    json.emit("LJ", "superstep_throughput_sorted_eps", sorted_eps);

    // Tracing overhead: the same PageRank run with span tracing on —
    // every worker records superstep/compute/route/drain/barrier spans,
    // the manager ckpt/commit lanes stay idle — vs. the untraced
    // baseline above. CI asserts the ratio stays under the bound
    // documented in docs/OBSERVABILITY.md.
    let traced_cfg = GopherConfig {
        trace: goffish::obs::trace::Tracer::enabled(),
        ..Default::default()
    };
    let (w, r) = reps(1, 3);
    let m_traced = measure(w, r, || {
        let prog = PageRankSg { supersteps: 5, kernel: RankKernel::Scalar, epsilon: None };
        run(&ljdg, &prog, &traced_cfg).unwrap();
    });
    assert!(
        !traced_cfg.trace.sink().unwrap().events().is_empty(),
        "traced bench run recorded no spans"
    );
    let traced_per_ss = m_traced.median / 5.0;
    let ratio = traced_per_ss / plain_per_ss;
    t.row(&[
        "pagerank 5 ss LJ, tracing on".into(),
        fmt_secs(m_traced.median),
        format!("{} per superstep ({ratio:.3}x untraced)", fmt_secs(traced_per_ss)),
    ]);
    json.emit("LJ", "traced_superstep_seconds", traced_per_ss);
    json.emit("LJ", "trace_overhead_ratio", ratio);

    // Checkpoint overhead: the same PageRank run with a snapshot every
    // superstep (states + queues to disk, epoch committed at the
    // barrier) vs. the uncheckpointed baseline above.
    let ckpt_dir = std::env::temp_dir()
        .join("goffish_bench_ckpt")
        .join(format!("micro_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let ckpt_cfg = GopherConfig {
        checkpoint: Some(goffish::ckpt::CheckpointConfig {
            every: 1,
            dir: ckpt_dir.clone(),
            label: "pagerank/gopher".into(),
            mode: goffish::ckpt::CheckpointMode::Sync,
            compress: false,
        }),
        ..Default::default()
    };
    // Barrier stall = the slowest worker's in-barrier checkpoint work,
    // summed over epochs (`JobMetrics::checkpoint_seconds`). Min over
    // reps: stall is pure added latency, so the least-noisy rep is the
    // honest one.
    let mut stall_sync = f64::INFINITY;
    let (w, r) = reps(1, 3);
    let m = measure(w, r, || {
        let prog = PageRankSg { supersteps: 5, kernel: RankKernel::Scalar, epsilon: None };
        let res = run(&ljdg, &prog, &ckpt_cfg).unwrap();
        assert_eq!(res.metrics.checkpoints.len(), 5);
        stall_sync = stall_sync.min(res.metrics.checkpoint_seconds());
    });
    let ckpt_per_ss = m.median / 5.0;
    // Clamp in BOTH reports: on a noisy box the checkpointed median can
    // dip below the baseline's, and a "negative overhead" row in the
    // trend artifact would claim checkpointing speeds supersteps up.
    let overhead = (ckpt_per_ss - plain_per_ss).max(0.0);
    t.row(&[
        "pagerank 5 ss LJ + ckpt every 1".into(),
        fmt_secs(m.median),
        format!(
            "{} per superstep (+{} over baseline)",
            fmt_secs(ckpt_per_ss),
            fmt_secs(overhead),
        ),
    ]);
    json.emit("LJ", "checkpointed_superstep_seconds", ckpt_per_ss);
    json.emit("LJ", "checkpoint_overhead", overhead);

    // Async double-buffering: same run, but the barrier pays only for
    // the snapshot encode — the flusher thread persists the epoch while
    // the next superstep computes. CI asserts async stall < sync stall.
    let ckpt_async_cfg = GopherConfig {
        checkpoint: Some(goffish::ckpt::CheckpointConfig {
            every: 1,
            dir: ckpt_dir.clone(),
            label: "pagerank/gopher".into(),
            mode: goffish::ckpt::CheckpointMode::Async,
            compress: false,
        }),
        ..Default::default()
    };
    let mut stall_async = f64::INFINITY;
    let (w, r) = reps(1, 3);
    let m_async = measure(w, r, || {
        let prog = PageRankSg { supersteps: 5, kernel: RankKernel::Scalar, epsilon: None };
        let res = run(&ljdg, &prog, &ckpt_async_cfg).unwrap();
        assert_eq!(res.metrics.checkpoints.len(), 5);
        stall_async = stall_async.min(res.metrics.checkpoint_seconds());
    });
    t.row(&[
        "pagerank 5 ss LJ + async ckpt every 1".into(),
        fmt_secs(m_async.median),
        format!(
            "barrier stall {} vs {} sync",
            fmt_secs(stall_async),
            fmt_secs(stall_sync),
        ),
    ]);
    json.emit("LJ", "checkpoint_stall_sync_seconds", stall_sync);
    json.emit("LJ", "checkpoint_stall_async_seconds", stall_async);
    let _ = std::fs::remove_dir_all(&ckpt_dir);

    // Streaming-ingest throughput: the RN analog written out as a TSV
    // edge list and streamed back through `goffish::ingest` at two
    // spill-buffer sizes — one smaller than the input (forces the
    // external-merge path: several run files per host) and one that
    // holds every record (a single run per host). The gap between the
    // two rows is the seek budget the buffer knob buys back.
    let ingest_dir = std::env::temp_dir()
        .join("goffish_bench_ingest")
        .join(format!("micro_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ingest_dir);
    std::fs::create_dir_all(&ingest_dir).unwrap();
    let list = ingest_dir.join("edges.tsv");
    goffish::graph::io::write_edge_list(&g, &list).unwrap();
    let spilled_bytes = g.num_edges() * 12;
    for (tag, spill_buffer) in [
        ("spill=input/8", (spilled_bytes / 8).max(12)),
        ("spill=64MiB", 64usize << 20),
    ] {
        let opts = goffish::ingest::IngestOptions {
            hosts: 4,
            directed: g.directed(),
            spill_buffer,
            ..Default::default()
        };
        let root = ingest_dir.join(format!("store_{spill_buffer}"));
        let mut last_spills = 0u64;
        let (w, r) = reps(1, 3);
        let m = measure(w, r, || {
            let _ = std::fs::remove_dir_all(&root);
            let (_, report) =
                goffish::ingest::ingest_edge_list(&list, &root, &opts).unwrap();
            assert_eq!(report.edges, g.num_edges() as u64);
            last_spills = report.spills;
        });
        let eps = g.num_edges() as f64 / m.median;
        t.row(&[
            format!("ingest RN ({}e, {tag})", g.num_edges()),
            fmt_secs(m.median),
            format!("{:.2} Me/s, {last_spills} spills", eps / 1e6),
        ]);
        json.emit(&format!("RN/{tag}"), "ingest_throughput", eps);
    }
    let _ = std::fs::remove_dir_all(&ingest_dir);

    // Mmap vs seek+read load of the same v3 packed store (RN analog +
    // 3 attribute columns). The wall clocks are the comparison; the
    // byte accounting is asserted identical — `LoadStats.bytes` counts
    // directory-listed section lengths on both paths.
    let (store_v3, _, root_v3) = common::store_for_fmt(
        "micro_mmap",
        &g,
        &parts,
        goffish::gofs::SliceFormat::V3Packed,
    );
    {
        let mut items = Vec::new();
        for sg in dg.subgraphs() {
            let vals: Vec<f32> = (0..sg.num_vertices()).map(|i| i as f32).collect();
            for a in 0..3 {
                items.push((sg.id, format!("attr{a}"), vals.clone()));
            }
        }
        store_v3.write_attributes(&items).unwrap();
    }
    let opt_map = goffish::gofs::LoadOptions::default();
    let opt_read = goffish::gofs::LoadOptions { mmap: false, ..Default::default() };
    let (w, r) = reps(1, 5);
    let m_map = measure(w, r, || {
        store_v3.load_all_with(&opt_map).unwrap();
    });
    let m_read = measure(w, r, || {
        store_v3.load_all_with(&opt_read).unwrap();
    });
    let (_, _, st_map) = store_v3.load_all_with(&opt_map).unwrap();
    let (_, _, st_read) = store_v3.load_all_with(&opt_read).unwrap();
    assert_eq!(
        st_map.bytes, st_read.bytes,
        "mmap and seek+read loads must report identical byte accounting"
    );
    t.row(&[
        format!("v3 load mmap RN ({}v)", g.num_vertices()),
        fmt_secs(m_map.median),
        format!("read path {}", fmt_secs(m_read.median)),
    ]);
    json.emit("RN", "mmap_vs_read_mmap_seconds", m_map.median);
    json.emit("RN", "mmap_vs_read_read_seconds", m_read.median);
    json.emit("RN", "mmap_vs_read_bytes", st_map.bytes as f64);
    let _ = std::fs::remove_dir_all(&root_v3);

    // Pool dispatch overhead.
    let (w, r) = reps(2, 10);
    let m = measure(w, r, || {
        pool::run_indexed(4, 1000, |_| {}).unwrap();
    });
    t.row(&[
        "pool 1000 empty jobs x4 cores".into(),
        fmt_secs(m.median),
        format!("{} per job", fmt_secs(m.median / 1000.0)),
    ]);
    json.emit("-", "pool_dispatch_seconds_per_job", m.median / 1000.0);

    t.print();
    json.finish();
}
