//! Fig 4(c): superstep counts, Gopher vs the vertex baseline.
//!
//! Paper reference: CC on RN collapses 554 -> 7; TR/LJ take 5-ish on
//! Gopher vs 11-30 on Giraph; PageRank is fixed at 30 on both. The
//! superstep *ratio* on traversal algorithms tracks vertex-diameter /
//! meta-diameter, which is the abstraction's whole point (§3.3).

mod common;

use goffish::algos::bfs::{BfsSg, BfsVx};
use goffish::algos::cc::{CcSg, CcVx};
use goffish::algos::pagerank::{PageRankSg, PageRankVx, RankKernel};
use goffish::algos::sssp::{SsspSg, SsspVx};
use goffish::bench::Table;
use goffish::gopher::{run, GopherConfig};
use goffish::graph::props;
use goffish::partition::{HashPartitioner, Partitioner};
use goffish::pregel::{run_vertex, PregelConfig};

fn main() {
    let mut t = Table::new(
        &format!("Fig 4(c) analog: supersteps, scale {}", common::scale()),
        &["dataset", "algo", "gopher", "vertex", "ratio", "meta_diam", "vert_diam"],
    );

    for (name, g) in common::datasets() {
        let (_, dg) = common::partitioned(&g);
        let vparts = HashPartitioner::default().partition(&g, common::K);
        let source = common::best_source(&g);
        let gcfg = GopherConfig { cores_per_worker: 2, ..Default::default() };
        let vcfg = PregelConfig { cores_per_worker: 2, ..Default::default() };
        let meta_d = props::diameter_estimate(&dg.meta_graph(), 4, 5);
        let vert_d = props::diameter_estimate(&g, 4, 9);

        for algo in ["cc", "sssp", "bfs", "pagerank"] {
            let (gss, vss) = match algo {
                "cc" => (
                    run(&dg, &CcSg, &gcfg).unwrap().metrics.num_supersteps(),
                    run_vertex(&g, &vparts, &CcVx, &vcfg).unwrap().metrics.num_supersteps(),
                ),
                "sssp" => (
                    run(&dg, &SsspSg { source }, &gcfg).unwrap().metrics.num_supersteps(),
                    run_vertex(&g, &vparts, &SsspVx { source }, &vcfg)
                        .unwrap()
                        .metrics
                        .num_supersteps(),
                ),
                "bfs" => (
                    run(&dg, &BfsSg { source }, &gcfg).unwrap().metrics.num_supersteps(),
                    run_vertex(&g, &vparts, &BfsVx { source }, &vcfg)
                        .unwrap()
                        .metrics
                        .num_supersteps(),
                ),
                _ => (
                    run(
                        &dg,
                        &PageRankSg { supersteps: 30, kernel: RankKernel::Scalar, epsilon: None },
                        &gcfg,
                    )
                    .unwrap()
                    .metrics
                    .num_supersteps(),
                    run_vertex(&g, &vparts, &PageRankVx { supersteps: 30 }, &vcfg)
                        .unwrap()
                        .metrics
                        .num_supersteps(),
                ),
            };
            t.row(&[
                name.to_string(),
                algo.to_string(),
                gss.to_string(),
                vss.to_string(),
                format!("{:.1}", vss as f64 / gss as f64),
                meta_d.to_string(),
                vert_d.to_string(),
            ]);
            if algo == "cc" && name == "RN" {
                assert!(
                    gss * 8 < vss,
                    "RN CC superstep collapse missing: {gss} vs {vss}"
                );
            }
            if algo == "pagerank" {
                assert_eq!(gss, 30);
                assert_eq!(vss, 30);
            }
        }
    }
    t.print();
    println!("\nshape assertions OK (RN collapse present; PR fixed at 30)");
}
