//! Ablations called out in DESIGN.md §6:
//!
//! * **A1 partitioner** (§4.3): hash vs range vs multilevel — edge cut,
//!   CC supersteps, messages. The paper's co-design argument is that
//!   locality-preserving partitioning is what gives sub-graphs their
//!   power; hashing degenerates sub-graphs toward single vertices.
//! * **A2 BlockRank** (§5.3): classic-PR-with-convergence (uniform seed)
//!   vs BlockRank seeding — supersteps to convergence.
//! * **A3 XLA kernel**: scalar vs AOT-XLA per-sub-graph PageRank inner
//!   loop (requires `make artifacts`).

mod common;

use std::sync::Arc;

use goffish::algos::blockrank::BlockRankSg;
use goffish::algos::cc::CcSg;
use goffish::algos::pagerank::{PageRankSg, RankKernel};
use goffish::bench::{fmt_secs, measure, Table};
use goffish::gofs::subgraph::discover;
use goffish::gopher::{run, GopherConfig};
use goffish::partition::{
    HashPartitioner, MultilevelPartitioner, Partitioner, RangePartitioner,
};
use goffish::runtime::XlaEngine;

fn main() {
    ablation_partitioner();
    ablation_blockrank();
    ablation_xla_kernel();
}

fn ablation_partitioner() {
    let g = goffish::graph::gen::rn_analog(common::scale(), 11);
    let mut t = Table::new(
        "A1: partitioning strategy (CC on RN analog)",
        &["strategy", "cut%", "subgraphs", "supersteps", "messages", "compute"],
    );
    let strategies: Vec<Box<dyn Partitioner>> = vec![
        Box::new(MultilevelPartitioner::default()),
        Box::new(HashPartitioner::default()),
        Box::new(RangePartitioner),
    ];
    let mut cut_multilevel = f64::NAN;
    let mut ss_multilevel = 0usize;
    let mut ss_hash = 0usize;
    for s in strategies {
        let parts = s.partition(&g, common::K);
        let m = parts.metrics(&g);
        let dg = discover(&g, &parts).unwrap();
        let res = run(&dg, &CcSg, &GopherConfig::default()).unwrap();
        if s.name() == "multilevel" {
            cut_multilevel = m.cut_fraction;
            ss_multilevel = res.metrics.num_supersteps();
        }
        if s.name() == "hash" {
            ss_hash = res.metrics.num_supersteps();
        }
        t.row(&[
            s.name().to_string(),
            format!("{:.1}", m.cut_fraction * 100.0),
            dg.num_subgraphs().to_string(),
            res.metrics.num_supersteps().to_string(),
            res.metrics.total_messages().to_string(),
            fmt_secs(res.metrics.compute_seconds),
        ]);
    }
    t.print();
    assert!(cut_multilevel < 0.2, "multilevel cut should be small");
    assert!(
        ss_multilevel <= ss_hash,
        "locality partitioning must not need more supersteps"
    );
    println!("A1 assertions OK (multilevel cut {:.1}%)", cut_multilevel * 100.0);
}

fn ablation_blockrank() {
    let g = goffish::graph::gen::lj_analog(common::scale() * 0.5, 33);
    let parts = MultilevelPartitioner::default().partition(&g, common::K);
    let dg = discover(&g, &parts).unwrap();
    let directory: Vec<u32> = dg.partitions.iter().map(|p| p.len() as u32).collect();
    let cfg = GopherConfig { max_supersteps: 500, ..Default::default() };

    let mut t = Table::new("A2: BlockRank vs classic PR convergence (LJ analog)", &[
        "variant",
        "supersteps",
        "messages",
        "compute",
    ]);
    let mut steps = Vec::new();
    for (label, seeded) in [("classic (uniform seed)", false), ("blockrank (seeded)", true)] {
        let mut prog = BlockRankSg::new(&directory);
        prog.seed_with_blockrank = seeded;
        prog.eps = 1e-8;
        let res = run(&dg, &prog, &cfg).unwrap();
        steps.push(res.metrics.num_supersteps());
        t.row(&[
            label.to_string(),
            res.metrics.num_supersteps().to_string(),
            res.metrics.total_messages().to_string(),
            fmt_secs(res.metrics.compute_seconds),
        ]);
    }
    t.print();
    assert!(steps[1] <= steps[0], "BlockRank seeding must not converge slower");
    println!("A2 assertions OK ({} -> {} supersteps)", steps[0], steps[1]);
}

fn ablation_xla_kernel() {
    let engine = match XlaEngine::load_default() {
        Ok(e) => Arc::new(e),
        Err(e) => {
            println!("\nA3 skipped: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let g = goffish::graph::gen::lj_analog(common::scale() * 0.5, 33);
    let parts = MultilevelPartitioner::default().partition(&g, common::K);
    let dg = discover(&g, &parts).unwrap();
    let cfg = GopherConfig::default();

    let mut t = Table::new(
        "A3: per-sub-graph PR inner loop, scalar vs XLA (LJ analog)",
        &["kernel", "median_run", "supersteps"],
    );
    for (label, kernel) in [
        ("scalar", RankKernel::Scalar),
        ("xla", RankKernel::Xla(engine.clone())),
    ] {
        let m = measure(1, 3, || {
            let prog = PageRankSg { supersteps: 10, kernel: kernel.clone(), epsilon: None };
            let res = run(&dg, &prog, &cfg).unwrap();
            assert_eq!(res.metrics.num_supersteps(), 10);
        });
        t.row(&[label.to_string(), fmt_secs(m.median), "10".to_string()]);
    }
    t.print();
    println!("A3 emitted (see EXPERIMENTS.md §Perf for interpretation)");
}
