//! Fig 5: distribution of per-sub-graph compute times within each
//! partition for the first *computing* superstep of PageRank.
//!
//! Paper reference: on TR one partition is a ~2.4x straggler (the other
//! 11 hosts idle >58% of the superstep); on LJ each partition hosts one
//! giant sub-graph while the second-slowest finishes within 0.1 s, so
//! ~75% of cores idle. RN is balanced. We print box-whisker rows per
//! partition (the Fig-5 panels) plus the straggler ratios.

mod common;

use goffish::algos::pagerank::{PageRankSg, RankKernel};
use goffish::bench::Table;
use goffish::gopher::{run, GopherConfig};

fn main() {
    for (name, g) in common::datasets() {
        let (_, dg) = common::partitioned(&g);
        let gcfg = GopherConfig { cores_per_worker: 2, ..Default::default() };
        // Two supersteps: superstep 1 initialises; superstep 2 is the
        // first real rank update (the paper plots "the first superstep"
        // of actual PageRank compute).
        let prog = PageRankSg { supersteps: 2, kernel: RankKernel::Scalar, epsilon: None };
        let res = run(&dg, &prog, &gcfg).unwrap();
        let ss = &res.metrics.supersteps[1];

        let mut t = Table::new(
            &format!("Fig 5 analog: PR superstep-1 sub-graph times, {name}"),
            &["partition", "subgraphs", "min", "q1", "median", "q3", "max", "part_total"],
        );
        for p in 0..common::K {
            if let Some(s) = ss.partition_summary(p) {
                t.row(&[
                    format!("P{p}"),
                    s.count.to_string(),
                    format!("{:.2e}", s.min),
                    format!("{:.2e}", s.q1),
                    format!("{:.2e}", s.median),
                    format!("{:.2e}", s.q3),
                    format!("{:.2e}", s.max),
                    format!("{:.2e}", ss.partition_compute_seconds[p]),
                ]);
            } else {
                t.row(&[
                    format!("P{p}"),
                    "0".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]);
            }
        }
        t.print();
        println!(
            "{name}: partition straggler ratio {:.2} (paper TR: ~2.4)",
            ss.straggler_ratio()
        );
        // Within-partition skew (the LJ pathology): largest sub-graph
        // time / median sub-graph time, worst over partitions.
        let skew = (0..common::K)
            .filter_map(|p| ss.partition_summary(p))
            .map(|s| if s.median > 0.0 { s.max / s.median.max(1e-12) } else { 1.0 })
            .fold(1.0f64, f64::max);
        println!("{name}: within-partition sub-graph skew {skew:.1}");
    }
    println!("\nFig 5 distributions emitted.");
}
