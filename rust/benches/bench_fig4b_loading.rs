//! Fig 4(b): graph loading time from disk to memory objects.
//!
//! Measured series per dataset (all with topology + 10 per-vertex
//! attribute columns, emulating an attributed graph):
//! * **v1 seq**  — slice format v1, strictly sequential load (the
//!   pre-GoFS-v2 behaviour);
//! * **v2 seq**  — columnar v2 slices, still sequential (isolates the
//!   codec effect);
//! * **v2 par**  — v2 with the parallel load path: one loader thread per
//!   partition, worker pool over slices within each. Asserted faster
//!   than v1 sequential on every dataset.
//! * **v3 seq / v3 par** — the packed format: one `partition.gfsp` per
//!   host, same parallel split (pool over sub-graphs within the file).
//! * **projection** — full attribute load vs `attr0`-only, in bytes,
//!   on v2 *and* v3: the paper's "10 attributes, load one" scenario.
//!   Asserted strictly ordered: v3-projected < v2-projected < full —
//!   the packed directory lets the loader seek past unread columns, so
//!   it never pays the per-file headers/section tables v2 rereads.
//!
//! Simulated series (12-host cluster, spinning-disk model):
//! * **GoFS (sim)**      — data-local slice load, slowest host gates;
//! * **GoFS Edge Imp. (sim)** — topology slices only (the paper's load
//!   improvement);
//! * **v3 proj (sim)**   — packed projected load: one file + directory
//!   per host, intra-file seeks past the 9 unread columns
//!   (`DiskModel::packed_read_seconds` over the real v3 directories);
//! * **HDFS (sim)**      — Giraph's loading path: block-random
//!   placement (~11/12 of bytes cross the network) plus per-record
//!   materialisation, including the TR mega-hub pathology (798 s vs
//!   38 s in the paper).
//!
//! Expected shape: GoFS ≪ HDFS everywhere; the gap explodes on TR; Edge
//! Imp. < full GoFS; v2 parallel < v1 sequential; v3proj bytes <
//! v2proj bytes < full bytes.

mod common;

use goffish::bench::{fmt_secs, measure, JsonEmitter, Table};
use goffish::gofs::{packed, AttrProjection, LoadOptions, SliceFormat, Store};
use goffish::graph::props;
use goffish::sim::{self, ClusterSpec};

const ATTRS: usize = 10;

/// Write the 10 synthetic attribute columns the paper's ingest carries
/// (one batch: a packed store rewrites each partition file once).
fn write_attrs(store: &Store, dg: &goffish::gofs::DistributedGraph) {
    let mut items = Vec::new();
    for sg in dg.subgraphs() {
        let vals: Vec<f32> = (0..sg.num_vertices()).map(|i| i as f32).collect();
        for a in 0..ATTRS {
            items.push((sg.id, format!("attr{a}"), vals.clone()));
        }
    }
    store.write_attributes(&items).unwrap();
}

fn main() {
    let mut json = JsonEmitter::from_env("fig4b_loading", common::scale());
    let spec = ClusterSpec::default();
    let mut t = Table::new(
        &format!("Fig 4(b) analog: loading time, scale {}", common::scale()),
        &[
            "dataset", "v1_seq", "v2_par", "v3_par", "v1/v3", "v2proj/full",
            "v3proj/full", "gofs_sim", "v3proj_sim", "hdfs_sim", "hdfs/gofs",
        ],
    );

    for (name, g) in common::datasets() {
        let (parts, dg) = common::partitioned(&g);
        let (store_v1, _, _root1) = common::store_for_fmt(name, &g, &parts, SliceFormat::V1);
        let (store_v2, _, _root2) = common::store_for_fmt(name, &g, &parts, SliceFormat::V2);
        let (store_v3, _, root3) =
            common::store_for_fmt(name, &g, &parts, SliceFormat::V3Packed);
        write_attrs(&store_v1, &dg);
        write_attrs(&store_v2, &dg);
        write_attrs(&store_v3, &dg);

        // ---- measured loads (topology + all 10 attributes). Fixed
        // 3-rep minimums even in quick mode: the v2-beats-v1 assertion
        // below needs more than one noisy sample.
        let full_seq = LoadOptions {
            attributes: AttrProjection::All,
            sequential: true,
            ..Default::default()
        };
        let full_par =
            LoadOptions { attributes: AttrProjection::All, ..Default::default() };
        let mut m_v1_seq = measure(1, 3, || {
            store_v1.load_all_with(&full_seq).unwrap();
        });
        let m_v2_seq = measure(1, 3, || {
            store_v2.load_all_with(&full_seq).unwrap();
        });
        let mut m_v2_par = measure(1, 3, || {
            store_v2.load_all_with(&full_par).unwrap();
        });
        let m_v3_seq = measure(1, 3, || {
            store_v3.load_all_with(&full_seq).unwrap();
        });
        let m_v3_par = measure(1, 3, || {
            store_v3.load_all_with(&full_par).unwrap();
        });
        if m_v2_par.min >= m_v1_seq.min {
            // A shared CI runner can smear a 3-rep minimum; escalate to
            // 10 reps before letting the shape assertion below decide.
            m_v1_seq = measure(1, 10, || {
                store_v1.load_all_with(&full_seq).unwrap();
            });
            m_v2_par = measure(1, 10, || {
                store_v2.load_all_with(&full_par).unwrap();
            });
        }

        // ---- projection: bytes touched, full vs one-of-ten attributes,
        // on both sectioned formats. Byte counts are deterministic, so
        // these carry the CI assertions (wall clocks stay informative).
        let proj = LoadOptions {
            attributes: AttrProjection::Only(vec!["attr0".into()]),
            ..Default::default()
        };
        let (_, _, st_full) = store_v2.load_all_with(&full_par).unwrap();
        let (_, _, st_proj) = store_v2.load_all_with(&proj).unwrap();
        let (_, _, st3_full) = store_v3.load_all_with(&full_par).unwrap();
        let (_, _, st3_proj) = store_v3.load_all_with(&proj).unwrap();
        // The v3 loads above ride the default mmap path; repeat the
        // projected and full loads through the seek+read path to pin
        // the byte-accounting contract (`LoadStats.bytes` counts
        // directory-listed section lengths on both).
        let proj_read = LoadOptions { mmap: false, ..proj.clone() };
        let full_read = LoadOptions { mmap: false, ..full_par.clone() };
        let (_, _, st3_proj_read) = store_v3.load_all_with(&proj_read).unwrap();
        let (_, _, st3_full_read) = store_v3.load_all_with(&full_read).unwrap();

        // ---- simulated cluster times (per-host stats from the store).
        let vf = common::volume_factor(name, &g);
        let mut attr_bytes = 0u64;
        let mut attr_files = 0u64;
        for sg in dg.subgraphs() {
            for a in 0..ATTRS {
                let (_, st) = store_v2.read_attribute(sg.id, &format!("attr{a}")).unwrap();
                attr_bytes += st.bytes;
                attr_files += st.files;
            }
        }
        let per_host_full: Vec<(u64, u64, u64)> = (0..common::K as u32)
            .map(|p| {
                let (sgs, st) = store_v2.load_partition(p).unwrap();
                let records: u64 = sgs
                    .iter()
                    .map(|s| (s.num_vertices() * (1 + ATTRS) + s.local.num_edges()) as u64)
                    .sum();
                let host_attr_bytes = attr_bytes / common::K as u64;
                let host_attr_files = attr_files / common::K as u64;
                (
                    st.files + host_attr_files,
                    ((st.bytes + host_attr_bytes) as f64 * vf) as u64,
                    (records as f64 * vf) as u64,
                )
            })
            .collect();
        let per_host_topo: Vec<(u64, u64, u64)> = (0..common::K as u32)
            .map(|p| {
                let (sgs, st) = store_v2.load_partition(p).unwrap();
                let records: u64 = sgs
                    .iter()
                    .map(|s| (s.num_vertices() + s.local.num_edges()) as u64)
                    .sum();
                (st.files, (st.bytes as f64 * vf) as u64, (records as f64 * vf) as u64)
            })
            .collect();
        let gofs_sim = sim::cluster::gofs_load_seconds(&spec, &per_host_full);
        let edgeimp_sim = sim::cluster::gofs_load_seconds(&spec, &per_host_topo);

        let total_bytes: u64 = per_host_full.iter().map(|x| x.1).sum::<u64>();
        let records =
            ((g.num_vertices() * (1 + ATTRS) + g.num_edges()) as f64 * vf) as u64;
        let max_deg = (props::degree_stats(&g).max as f64 * vf) as u64;
        let hdfs_sim = sim::cluster::hdfs_load_seconds(&spec, total_bytes, records, max_deg);

        // ---- v3 packed projected load, simulated on the paper's disks
        // from the REAL packed directories (this was a forward-looking
        // modeled row in PR 3; the format now exists): per host, one
        // file + its directory, the projected section bytes, and one
        // intra-file seek per sub-graph's run of 9 unread columns.
        let v3proj_sim = (0..common::K as u32)
            .map(|p| {
                let bytes = std::fs::read(
                    root3.join(format!("host{p}")).join(packed::PARTITION_FILE),
                )
                .unwrap();
                let dir = packed::parse(&bytes).unwrap();
                let dir_bytes = dir.body_start;
                let proj_bytes: u64 = dir
                    .entries
                    .iter()
                    .filter(|e| e.name.is_empty() || e.name == "attr0")
                    .map(|e| e.len)
                    .sum();
                let sgs = store_v3.meta().subgraph_counts[p as usize] as u64;
                let records: u64 = per_host_topo[p as usize].2;
                spec.disk.packed_read_seconds(
                    1,
                    dir_bytes,
                    (proj_bytes as f64 * vf) as u64,
                    records,
                    sgs, // attr1..attr9 are adjacent: one skip run per sub-graph
                )
            })
            .fold(0.0f64, f64::max);

        t.row(&[
            name.to_string(),
            fmt_secs(m_v1_seq.min),
            fmt_secs(m_v2_par.min),
            fmt_secs(m_v3_par.min),
            format!("{:.2}x", m_v1_seq.min / m_v3_par.min),
            format!("{:.2}", st_proj.bytes as f64 / st_full.bytes as f64),
            format!("{:.2}", st3_proj.bytes as f64 / st_full.bytes as f64),
            fmt_secs(gofs_sim),
            fmt_secs(v3proj_sim),
            fmt_secs(hdfs_sim),
            format!("{:.1}x", hdfs_sim / gofs_sim),
        ]);

        json.emit(name, "v1_sequential_seconds", m_v1_seq.min);
        json.emit(name, "v2_sequential_seconds", m_v2_seq.min);
        json.emit(name, "v2_parallel_seconds", m_v2_par.min);
        json.emit(name, "v3_sequential_seconds", m_v3_seq.min);
        json.emit(name, "v3_parallel_seconds", m_v3_par.min);
        json.emit(name, "full_load_bytes", st_full.bytes as f64);
        json.emit(name, "projected_load_bytes", st_proj.bytes as f64);
        json.emit(name, "v3_full_load_bytes", st3_full.bytes as f64);
        json.emit(name, "v3_projected_load_bytes", st3_proj.bytes as f64);
        json.emit(name, "v3_projected_mmap_load_bytes", st3_proj.bytes as f64);
        json.emit(name, "v3_projected_read_load_bytes", st3_proj_read.bytes as f64);
        json.emit(name, "gofs_sim_seconds", gofs_sim);
        json.emit(name, "edgeimp_sim_seconds", edgeimp_sim);
        json.emit(name, "v3_projected_sim_seconds", v3proj_sim);
        json.emit(name, "hdfs_sim_seconds", hdfs_sim);
        json.emit(name, "hdfs_over_gofs", hdfs_sim / gofs_sim);

        // Shape assertions (the acceptance criteria of GoFS v2 + v3).
        assert!(hdfs_sim > gofs_sim, "{name}: GoFS must beat HDFS load");
        assert!(edgeimp_sim <= gofs_sim, "{name}: Edge Imp. must not regress");
        assert!(
            m_v2_par.min < m_v1_seq.min,
            "{name}: v2 parallel load ({}) must beat v1 sequential ({})",
            fmt_secs(m_v2_par.min),
            fmt_secs(m_v1_seq.min)
        );
        // Deterministic byte ordering: the packed projected load reads
        // strictly fewer bytes than the v2 projected load, which reads
        // strictly fewer than the full load.
        assert!(
            st3_proj.bytes < st_proj.bytes,
            "{name}: v3 projected ({} B) must be < v2 projected ({} B)",
            st3_proj.bytes,
            st_proj.bytes
        );
        assert!(
            st_proj.bytes < st_full.bytes,
            "{name}: projected load ({} B) must read strictly fewer bytes than full ({} B)",
            st_proj.bytes,
            st_full.bytes
        );
        assert!(
            st3_full.bytes < st_full.bytes,
            "{name}: v3 full ({} B) must be < v2 full ({} B) — no per-file framing",
            st3_full.bytes,
            st_full.bytes
        );
        // Mmap-vs-read contract: identical accounting on both packed
        // paths, and a mapped projected load still consumes strictly
        // fewer bytes than a seek+read full v3 load.
        assert_eq!(
            st3_proj.bytes, st3_proj_read.bytes,
            "{name}: mmap and seek+read projected loads must account identically"
        );
        assert_eq!(
            st3_full.bytes, st3_full_read.bytes,
            "{name}: mmap and seek+read full loads must account identically"
        );
        assert!(
            st3_proj.bytes < st3_full_read.bytes,
            "{name}: mmap-projected ({} B) must be < seek+read v3 full ({} B)",
            st3_proj.bytes,
            st3_full_read.bytes
        );
    }
    t.print();
    json.finish();
    println!(
        "\nshape assertions OK (GoFS < HDFS; Edge Imp. <= GoFS; v2 par < v1 seq; \
         v3proj bytes < v2proj bytes < full bytes; mmap == seek+read accounting)"
    );
}
