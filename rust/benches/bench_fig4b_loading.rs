//! Fig 4(b): graph loading time from disk to memory objects.
//!
//! Three systems per dataset:
//! * **GoFS**      — measured data-local slice load (all slices: topology
//!   + 10 per-vertex attribute slices, emulating an attributed graph) and
//!   the simulated 12-host cluster time;
//! * **GoFS Edge Imp.** — the paper's load improvement: read only the
//!   topology slice (the "only loads the slice it needs" co-design win);
//! * **HDFS (sim)** — Giraph's loading path: block-random placement, so
//!   ~11/12 of the bytes cross the network, plus per-record
//!   materialisation — including the TR mega-hub pathology (798 s vs
//!   38 s in the paper).
//!
//! Expected shape: GoFS ≪ HDFS everywhere; the gap explodes on TR; Edge
//! Imp. < full GoFS.

mod common;

use goffish::bench::{fmt_secs, Table};
use goffish::graph::props;
use goffish::sim::{self, ClusterSpec};

const ATTRS: usize = 10;

fn main() {
    let spec = ClusterSpec::default();
    let mut t = Table::new(
        &format!("Fig 4(b) analog: loading time, scale {}", common::scale()),
        &["dataset", "gofs_meas", "gofs_sim", "edgeimp_sim", "hdfs_sim", "hdfs/gofs"],
    );

    for (name, g) in common::datasets() {
        let (parts, dg) = common::partitioned(&g);
        let (store, _, _root) = common::store_for(name, &g, &parts);
        let vf = common::volume_factor(name, &g);

        // Attribute slices: 10 named f32 attributes per sub-graph, so the
        // full load is topology + attributes like the paper's ingest.
        for sg in dg.subgraphs() {
            for a in 0..ATTRS {
                let vals: Vec<f32> = (0..sg.num_vertices()).map(|i| i as f32).collect();
                store
                    .write_attribute(sg.id, &format!("attr{a}"), &vals)
                    .unwrap();
            }
        }

        // Measured GoFS load (topology; attributes measured separately).
        let t0 = std::time::Instant::now();
        let (_, topo_stats) = store.load_all().unwrap();
        let mut attr_bytes = 0u64;
        let mut attr_files = 0u64;
        for sg in dg.subgraphs() {
            for a in 0..ATTRS {
                let (_, st) = store.read_attribute(sg.id, &format!("attr{a}")).unwrap();
                attr_bytes += st.bytes;
                attr_files += st.files;
            }
        }
        let gofs_measured = t0.elapsed().as_secs_f64();

        // Simulated cluster times.
        let per_host_full: Vec<(u64, u64, u64)> = (0..common::K as u32)
            .map(|p| {
                let (sgs, st) = store.load_partition(p).unwrap();
                let records: u64 = sgs
                    .iter()
                    .map(|s| (s.num_vertices() * (1 + ATTRS) + s.local.num_edges()) as u64)
                    .sum();
                let host_attr_bytes = attr_bytes / common::K as u64;
                let host_attr_files = attr_files / common::K as u64;
                (
                    st.files + host_attr_files,
                    ((st.bytes + host_attr_bytes) as f64 * vf) as u64,
                    (records as f64 * vf) as u64,
                )
            })
            .collect();
        let per_host_topo: Vec<(u64, u64, u64)> = (0..common::K as u32)
            .map(|p| {
                let (sgs, st) = store.load_partition(p).unwrap();
                let records: u64 = sgs
                    .iter()
                    .map(|s| (s.num_vertices() + s.local.num_edges()) as u64)
                    .sum();
                (st.files, (st.bytes as f64 * vf) as u64, (records as f64 * vf) as u64)
            })
            .collect();
        let gofs_sim = sim::cluster::gofs_load_seconds(&spec, &per_host_full);
        let edgeimp_sim = sim::cluster::gofs_load_seconds(&spec, &per_host_topo);

        let total_bytes: u64 =
            per_host_full.iter().map(|x| x.1).sum::<u64>();
        let records =
            ((g.num_vertices() * (1 + ATTRS) + g.num_edges()) as f64 * vf) as u64;
        let max_deg = (props::degree_stats(&g).max as f64 * vf) as u64;
        let hdfs_sim = sim::cluster::hdfs_load_seconds(&spec, total_bytes, records, max_deg);

        t.row(&[
            name.to_string(),
            fmt_secs(gofs_measured),
            fmt_secs(gofs_sim),
            fmt_secs(edgeimp_sim),
            fmt_secs(hdfs_sim),
            format!("{:.1}x", hdfs_sim / gofs_sim),
        ]);

        assert!(hdfs_sim > gofs_sim, "{name}: GoFS must beat HDFS load");
        assert!(edgeimp_sim <= gofs_sim, "{name}: Edge Imp. must not regress");
        let _ = topo_stats;
    }
    t.print();
    println!("\nshape assertions OK (GoFS < HDFS; Edge Imp. <= GoFS)");
}
