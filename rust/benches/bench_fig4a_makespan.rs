//! Fig 4(a): total makespan (load + compute), GoFFish vs the vertex
//! baseline, for {CC, SSSP, PageRank} x {RN, TR, LJ}.
//!
//! Two columns per system: *measured* in-process seconds, and the
//! *simulated 12-node-cluster* seconds (measured compute + modelled
//! disk/network/sync from `sim`, DESIGN.md §3). The paper's claims to
//! reproduce in shape:
//!
//!   CC:  GoFFish wins everywhere, 81x on RN, ~21x TR, ~1.4x LJ
//!   SSSP: 78x RN, 10x TR, slightly *loses* on LJ
//!   PR:  4x RN, ~1.5x TR, *loses* on LJ (2.6x slower)
//!
//! Also checks the paper's §6.3 correlation: CC compute speedup vs
//! vertex diameter (R^2 = 0.9999 in the paper).

mod common;

use std::collections::BTreeMap;

use goffish::algos::cc::{CcSg, CcVx};
use goffish::algos::pagerank::{PageRankSg, PageRankVx, RankKernel};
use goffish::algos::sssp::{SsspSg, SsspVx};
use goffish::bench::{fmt_secs, fmt_speedup, Table};
use goffish::gopher::{run_on_store, GopherConfig};
use goffish::graph::props;
use goffish::metrics::JobMetrics;
use goffish::partition::{HashPartitioner, Partitioner};
use goffish::pregel::{run_vertex, PregelConfig};
use goffish::sim::{self, ClusterSpec};

fn simulated(spec: &ClusterSpec, m: &JobMetrics, load: f64) -> f64 {
    sim::simulate_job(spec, m, load).makespan()
}

fn main() {
    let spec = ClusterSpec::default();
    let mut t = Table::new(
        &format!("Fig 4(a) analog: makespan, scale {}, k={}", common::scale(), common::K),
        &["dataset", "algo", "gf_meas", "vx_meas", "gf_sim", "vx_sim", "speedup_sim", "paper"],
    );
    let paper: BTreeMap<(&str, &str), &str> = BTreeMap::from([
        (("RN", "cc"), "81x"),
        (("TR", "cc"), "21x"),
        (("LJ", "cc"), "1.4x"),
        (("RN", "sssp"), "78x"),
        (("TR", "sssp"), "10x"),
        (("LJ", "sssp"), "0.9x"),
        (("RN", "pagerank"), "4x"),
        (("TR", "pagerank"), "1.5x"),
        (("LJ", "pagerank"), "0.4x"),
    ]);

    let mut cc_speedups = Vec::new();
    let mut diameters = Vec::new();

    for (name, g) in common::datasets() {
        let (parts, dg) = common::partitioned(&g);
        let (store, _, _root) = common::store_for(name, &g, &parts);
        let vparts = HashPartitioner::default().partition(&g, common::K);
        let source = common::best_source(&g);
        let gcfg = GopherConfig { cores_per_worker: 2, ..Default::default() };
        let vcfg = PregelConfig { cores_per_worker: 2, ..Default::default() };

        // Modelled load: GoFS data-local slices vs HDFS block placement,
        // extrapolated to paper-scale volumes.
        let vf = common::volume_factor(name, &g);
        let per_host: Vec<(u64, u64, u64)> = (0..common::K as u32)
            .map(|p| {
                let (sgs, st) = store.load_partition(p).unwrap();
                let records: u64 = sgs
                    .iter()
                    .map(|s| (s.num_vertices() + s.local.num_edges()) as u64)
                    .sum();
                // Slice *count* tracks sub-graph structure, not volume:
                // the paper-scale graph has the same partition/WCC shape,
                // so only bytes/records are extrapolated.
                (
                    st.files,
                    (st.bytes as f64 * vf) as u64,
                    (records as f64 * vf) as u64,
                )
            })
            .collect();
        let gofs_load = sim::cluster::gofs_load_seconds(&spec, &per_host);
        let total_bytes: u64 = per_host.iter().map(|x| x.1).sum();
        let records = ((g.num_vertices() + g.num_edges()) as f64 * vf) as u64;
        let max_deg = (props::degree_stats(&g).max as f64 * vf) as u64;
        let hdfs_load = sim::cluster::hdfs_load_seconds(&spec, total_bytes, records, max_deg);

        for algo in ["cc", "sssp", "pagerank"] {
            let (gm, vm): (JobMetrics, JobMetrics) = match algo {
                "cc" => (
                    run_on_store(&store, &CcSg, &gcfg).unwrap().metrics,
                    run_vertex(&g, &vparts, &CcVx, &vcfg).unwrap().metrics,
                ),
                "sssp" => (
                    run_on_store(&store, &SsspSg { source }, &gcfg).unwrap().metrics,
                    run_vertex(&g, &vparts, &SsspVx { source }, &vcfg).unwrap().metrics,
                ),
                _ => (
                    run_on_store(
                        &store,
                        &PageRankSg { supersteps: 30, kernel: RankKernel::Scalar, epsilon: None },
                        &gcfg,
                    )
                    .unwrap()
                    .metrics,
                    run_vertex(&g, &vparts, &PageRankVx { supersteps: 30 }, &vcfg)
                        .unwrap()
                        .metrics,
                ),
            };
            let gms = common::scale_job(&gm, vf);
            let vms = common::scale_job(&vm, vf);
            let gf_sim = simulated(&spec, &gms, gofs_load);
            let vx_sim = simulated(&spec, &vms, hdfs_load);
            let speedup = vx_sim / gf_sim;
            if algo == "cc" {
                // Compute-only speedup for the §6.3 correlation.
                let gsim = sim::simulate_job(&spec, &gms, 0.0).makespan();
                let vsim = sim::simulate_job(&spec, &vms, 0.0).makespan();
                cc_speedups.push(vsim / gsim);
                diameters.push(props::diameter_estimate(&g, 4, 9) as f64);
            }
            t.row(&[
                name.to_string(),
                algo.to_string(),
                fmt_secs(gm.makespan_seconds()),
                fmt_secs(vm.makespan_seconds()),
                fmt_secs(gf_sim),
                fmt_secs(vx_sim),
                fmt_speedup(speedup),
                paper[&(name, algo)].to_string(),
            ]);
        }
    }
    t.print();

    // §6.3: CC compute speedup correlates with vertex diameter.
    let r = goffish::util::stats::pearson(&diameters, &cc_speedups);
    println!(
        "\nCC compute-speedup vs diameter: r={r:.4} r^2={:.4} (paper: r^2=0.9999)",
        r * r
    );
    assert!(r > 0.8, "speedup must correlate with diameter (r={r})");
}
