//! Shared plumbing for the figure/table benches.
//!
//! Every bench reads `GOFFISH_SCALE` (default 0.2) so the whole suite
//! can be dialled from smoke-size to laptop-max. The Table-1 dataset
//! analogs themselves live in `goffish::testing::fixtures` (fixed
//! seeds, shared with the integration tests) so figures are comparable
//! across benches *and* the tests exercise the same graph families.

use goffish::gofs::{subgraph::discover, DistributedGraph, SliceFormat, Store};
use goffish::graph::Graph;
use goffish::partition::{MultilevelPartitioner, Partitioner, Partitioning};
use goffish::testing::fixtures;
use std::path::PathBuf;

/// Simulated host count (the paper's testbed has 12).
pub const K: usize = 12;

pub fn scale() -> f64 {
    std::env::var("GOFFISH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2)
}

pub fn datasets() -> Vec<(&'static str, Graph)> {
    fixtures::datasets(scale())
}

pub fn partitioned(g: &Graph) -> (Partitioning, DistributedGraph) {
    let parts = MultilevelPartitioner::default().partition(g, K);
    let dg = discover(g, &parts).expect("discovery");
    (parts, dg)
}

/// Build a store in a fresh temp dir; returns it with the discovery.
pub fn store_for(name: &str, g: &Graph, parts: &Partitioning) -> (Store, DistributedGraph, PathBuf) {
    store_for_fmt(name, g, parts, SliceFormat::default())
}

/// Build a store in a fresh temp dir with an explicit slice format (the
/// Fig-4(b) bench compares v1 and v2 stores of the same graph).
pub fn store_for_fmt(
    name: &str,
    g: &Graph,
    parts: &Partitioning,
    format: SliceFormat,
) -> (Store, DistributedGraph, PathBuf) {
    let root = std::env::temp_dir().join(format!(
        "goffish_bench_{name}_{format}_{}_{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .subsec_nanos()
    ));
    let _ = std::fs::remove_dir_all(&root);
    let (store, dg) = Store::create_with_format(&root, name, g, parts, format).expect("store");
    (store, dg, root)
}

/// Max-out-degree vertex: a safe SSSP/BFS source on the directed analogs.
pub fn best_source(g: &Graph) -> u32 {
    (0..g.num_vertices() as u32)
        .max_by_key(|&v| g.out_degree(v))
        .unwrap_or(0)
}

/// Paper-scale vertex counts (Table 1) for volume extrapolation.
pub fn paper_vertices(name: &str) -> f64 {
    match name {
        "RN" => 1_965_206.0,
        "TR" => 19_442_778.0,
        "LJ" => 4_847_571.0,
        _ => 1.0,
    }
}

/// Volume factor: how much bigger the paper's dataset is than our analog.
/// The cluster simulation multiplies measured bytes/records/compute by
/// this so fixed costs (seeks, barrier latency) are weighed against
/// paper-magnitude volumes, not analog-magnitude ones (DESIGN.md §3).
pub fn volume_factor(name: &str, g: &Graph) -> f64 {
    (paper_vertices(name) / g.num_vertices() as f64).max(1.0)
}

/// Scale a job's per-superstep volumes (compute seconds, messages,
/// bytes) by `f`, leaving superstep *counts* untouched. First-order
/// extrapolation of an analog-scale run to testbed scale; superstep
/// counts for traversal algorithms are still analog-diameter counts, so
/// the reported speedups are *conservative* for RN (the paper's vertex
/// diameter is ~7x our analog's).
pub fn scale_job(m: &goffish::metrics::JobMetrics, f: f64) -> goffish::metrics::JobMetrics {
    let mut out = m.clone();
    for ss in &mut out.supersteps {
        for c in &mut ss.partition_compute_seconds {
            *c *= f;
        }
        ss.messages = (ss.messages as f64 * f) as u64;
        ss.bytes = (ss.bytes as f64 * f) as u64;
    }
    out
}
