//! Table 1: characteristics of the evaluation dataset analogs.
//!
//! Paper reference (full scale):
//!   RN  1,965,206 v   2,766,607 e   diameter 849  WCC 2,638
//!   TR 19,442,778 v  22,782,842 e   diameter  25  WCC 1
//!   LJ  4,847,571 v  68,475,391 e   diameter  10  WCC 1,877
//!
//! The analogs must preserve the *shape*: RN = sparse/huge-diameter/many
//! WCCs, TR = hub/small-diameter/one WCC, LJ = dense/power-law/small
//! diameter. Run: `cargo bench --bench bench_table1`.

mod common;

use goffish::bench::Table;
use goffish::graph::props;

fn main() {
    let ds = common::datasets();
    let mut t = Table::new(
        &format!("Table 1 analog (scale {})", common::scale()),
        &["dataset", "vertices", "edges", "diameter", "wcc", "max_degree", "paper_shape"],
    );
    let shapes = [
        ("RN", "sparse, huge diameter, many WCCs"),
        ("TR", "mega-hub, tiny diameter, 1 WCC"),
        ("LJ", "dense power-law, tiny diameter"),
    ];
    let mut diameters = Vec::new();
    for ((name, g), (_, shape)) in ds.iter().zip(shapes) {
        let deg = props::degree_stats(g);
        let d = props::diameter_estimate(g, 4, 9);
        diameters.push(d);
        t.row(&[
            name.to_string(),
            g.num_vertices().to_string(),
            g.num_edges().to_string(),
            d.to_string(),
            props::wcc_count(g).to_string(),
            deg.max.to_string(),
            shape.to_string(),
        ]);
    }
    t.print();

    // Shape assertions (the reproduction contract).
    let (d_rn, d_tr, d_lj) = (diameters[0], diameters[1], diameters[2]);
    assert!(d_rn > 5 * d_tr, "RN diameter must dwarf TR ({d_rn} vs {d_tr})");
    assert!(d_rn > 5 * d_lj, "RN diameter must dwarf LJ ({d_rn} vs {d_lj})");
    assert_eq!(props::wcc_count(&ds[1].1), 1, "TR must be one WCC");
    assert!(props::wcc_count(&ds[0].1) > 1, "RN must fragment");
    println!("\nshape assertions OK");
}
