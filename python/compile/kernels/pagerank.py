"""Blocked PageRank rank-update Pallas kernel.

One damped PageRank iteration over a padded dense adjacency block::

    out[i] = base + alpha * sum_j A[i, j] * contrib[j]

where ``A[i, j] = 1`` iff there is an edge ``j -> i`` inside the sub-graph
(note the transpose-free in-link orientation: Gopher materialises the
*in-adjacency* when it densifies a sub-graph, so the kernel is a plain
matvec), ``contrib[j] = rank[j] / outdeg[j]`` is precomputed by the L2
graph (zero for dangling vertices), ``base`` carries the teleport term and
the dangling-mass redistribution, and ``alpha`` is the damping factor.

Tiling: the grid iterates over row blocks of ``A``; each program instance
holds one ``(bm, n)`` tile of ``A`` and the full ``contrib`` vector in
VMEM and emits a ``(bm,)`` slice of the output. For the ladder used by
AOT (n <= 512, bm = min(n, 128)) the per-instance VMEM footprint is
``bm*n*4 + n*4 + bm*4`` <= 258 KB, far under a TPU core's ~16 MB VMEM,
leaving room for double buffering. The inner product is a rank-1 matvec:
on a real TPU this maps onto the MXU as an (bm, n) x (n, 1) systolic pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pagerank_kernel(a_ref, contrib_ref, scal_ref, o_ref):
    """Kernel body: one row-block of the damped rank update.

    ``scal_ref`` packs the two scalars ``[base, alpha]`` as a (2,) vector;
    packing them as an array keeps the AOT signature uniform (all-array
    parameters round-trip through HLO text cleanly).
    """
    base = scal_ref[0]
    alpha = scal_ref[1]
    a = a_ref[...]            # (bm, n) in-adjacency tile
    contrib = contrib_ref[...]  # (n,) rank/outdeg contributions
    # Row-block matvec; preferred_element_type pins f32 accumulation so the
    # same kernel is numerically stable if A is ever fed as bf16.
    acc = jnp.dot(a, contrib, preferred_element_type=jnp.float32)
    o_ref[...] = base + alpha * acc.astype(o_ref.dtype)


def pagerank_step_pallas(adj, contrib, scalars, *, block_rows=None):
    """One damped PageRank iteration over a dense ``(n, n)`` block.

    Args:
      adj: ``(n, n)`` in-adjacency matrix, ``adj[i, j] = 1`` iff edge
        ``j -> i`` (float dtype).
      contrib: ``(n,)`` per-vertex contribution ``rank/outdeg``.
      scalars: ``(2,)`` vector ``[base, alpha]``.
      block_rows: row-block size; default ``min(n, 128)``.

    Returns:
      ``(n,)`` updated ranks.
    """
    n = adj.shape[0]
    assert adj.shape == (n, n), adj.shape
    assert contrib.shape == (n,), contrib.shape
    bm = block_rows or min(n, 128)
    assert n % bm == 0, (n, bm)
    grid = (n // bm,)
    return pl.pallas_call(
        _pagerank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), contrib.dtype),
        interpret=True,
    )(adj, contrib, scalars)
