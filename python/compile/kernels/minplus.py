"""Min-plus relaxation Pallas kernel (SSSP / Bellman-Ford step).

One relaxation sweep over a padded dense weight block::

    out[i] = min(dist[i], min_j (dist[j] + W[i, j]))

where ``W[i, j]`` is the weight of edge ``j -> i`` (in-link orientation,
matching the PageRank kernel) or ``+inf`` when no such edge exists. This is
one step of the min-plus (tropical) matrix-vector product that underlies
Bellman-Ford; iterating it ``n-1`` times from the source yields all
shortest paths within the block.

Gopher uses it as the sub-graph-internal relaxation engine for SSSP on
dense sub-graphs: the scalar Dijkstra path (Algorithm 3 in the paper) wins
for sparse sub-graphs, while the blocked min-plus sweep is the "fast
shared-memory kernel" alternative the paper's §7 envisions, and is what
lowers onto the MXU-style tiling (VPU max/add lanes on TPU; here, XLA:CPU
vector loops).

Tiling mirrors pagerank.py: grid over row blocks, full ``dist`` vector
resident, ``(bm, n)`` weight tile per program instance.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _minplus_kernel(w_ref, dist_ref, dist_blk_ref, o_ref):
    w = w_ref[...]              # (bm, n) in-edge weights, +inf for non-edges
    dist = dist_ref[...]        # (n,) current tentative distances
    mine = dist_blk_ref[...]    # (bm,) this block's current distances
    # Tropical matvec: candidate[i] = min_j dist[j] + w[i, j].
    cand = jnp.min(w + dist[None, :], axis=1)
    o_ref[...] = jnp.minimum(mine, cand)


def minplus_relax_pallas(weights, dist, *, block_rows=None):
    """One min-plus relaxation sweep over a dense ``(n, n)`` weight block.

    Args:
      weights: ``(n, n)`` matrix, ``weights[i, j]`` = weight of edge
        ``j -> i``, ``+inf`` where absent.
      dist: ``(n,)`` tentative distances (``+inf`` = unreached).
      block_rows: row-block size; default ``min(n, 128)``.

    Returns:
      ``(n,)`` improved distances.
    """
    n = weights.shape[0]
    assert weights.shape == (n, n), weights.shape
    assert dist.shape == (n,), dist.shape
    bm = block_rows or min(n, 128)
    assert n % bm == 0, (n, bm)
    grid = (n // bm,)
    return pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), dist.dtype),
        interpret=True,
    )(weights, dist, dist)
