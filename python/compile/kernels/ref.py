"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the correctness ground truth: simple, obviously-right jnp
expressions with no Pallas, no tiling, no tricks. pytest compares every
kernel against these under hypothesis-driven shape/seed sweeps, and the
L2 model functions are *defined* in terms of kernels but *tested* against
compositions of these references.
"""

import jax.numpy as jnp


def pagerank_step_ref(adj, contrib, scalars):
    """out[i] = base + alpha * sum_j adj[i, j] * contrib[j]."""
    base, alpha = scalars[0], scalars[1]
    return base + alpha * adj @ contrib


def minplus_relax_ref(weights, dist):
    """out[i] = min(dist[i], min_j dist[j] + weights[i, j])."""
    return jnp.minimum(dist, jnp.min(weights + dist[None, :], axis=1))


def maxprop_step_ref(adj, labels):
    """out[i] = max(labels[i], max over neighbours j of labels[j])."""
    masked = jnp.where(adj > 0, labels[None, :], -jnp.inf)
    return jnp.maximum(labels, jnp.max(masked, axis=1))


def pagerank_full_ref(adj, out_deg, n_total, alpha, iters, dangling="none"):
    """Reference damped PageRank over a dense block, `iters` iterations.

    Matches model.pagerank_local semantics: ranks start uniform at
    1/n_total over the *live* vertices (out_deg >= 0 marks live, padding
    rows carry out_deg = -1 and are frozen at rank 0).
    """
    live = out_deg >= 0
    ranks = jnp.where(live, 1.0 / n_total, 0.0)
    base = (1.0 - alpha) / n_total
    for _ in range(iters):
        safe_deg = jnp.where(out_deg > 0, out_deg, 1)
        contrib = jnp.where(out_deg > 0, ranks / safe_deg, 0.0)
        ranks = jnp.where(live, base + alpha * adj @ contrib, 0.0)
    return ranks


def sssp_full_ref(weights, source, iters):
    """Iterated min-plus relaxation from one source (Bellman-Ford)."""
    n = weights.shape[0]
    dist = jnp.where(jnp.arange(n) == source, 0.0, jnp.inf)
    for _ in range(iters):
        dist = minplus_relax_ref(weights, dist)
    return dist


def cc_full_ref(adj, labels, iters):
    """Iterated max-label flood."""
    for _ in range(iters):
        labels = maxprop_step_ref(adj, labels)
    return labels
