"""Masked max-propagation Pallas kernel (connected-components label flood).

One label-flood step over a padded dense adjacency block::

    out[i] = max(label[i], max_{j : A[i,j]=1} label[j])

This is the inner step of HCC-style connected components (the paper's
§5.1): iterated to fixpoint it floods the largest vertex label through
every component of the block. Non-edges must not contribute, so the kernel
masks them to ``-inf`` before the row-max.

Labels travel as f32 (vertex ids are < 2^24 at sub-graph block scale, so
f32 is exact); the Rust side converts u32 labels to f32 and back.

Tiling mirrors pagerank.py: grid over row blocks, full label vector
resident per program instance.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxprop_kernel(a_ref, lab_ref, lab_blk_ref, o_ref):
    a = a_ref[...]            # (bm, n) 0/1 adjacency tile
    lab = lab_ref[...]        # (n,) labels
    mine = lab_blk_ref[...]   # (bm,) this block's labels
    neg = jnp.asarray(-jnp.inf, dtype=lab.dtype)
    masked = jnp.where(a > 0, lab[None, :], neg)
    cand = jnp.max(masked, axis=1)
    o_ref[...] = jnp.maximum(mine, cand)


def maxprop_step_pallas(adj, labels, *, block_rows=None):
    """One max-label flood step over a dense ``(n, n)`` block.

    Args:
      adj: ``(n, n)`` 0/1 adjacency (symmetric for undirected components;
        ``adj[i, j] = 1`` iff ``j`` is a neighbour of ``i``).
      labels: ``(n,)`` f32 labels.
      block_rows: row-block size; default ``min(n, 128)``.

    Returns:
      ``(n,)`` updated labels.
    """
    n = adj.shape[0]
    assert adj.shape == (n, n), adj.shape
    assert labels.shape == (n,), labels.shape
    bm = block_rows or min(n, 128)
    assert n % bm == 0, (n, bm)
    grid = (n // bm,)
    return pl.pallas_call(
        _maxprop_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), labels.dtype),
        interpret=True,
    )(adj, labels, labels)
