"""Layer-1 Pallas kernels for GoFFish per-sub-graph numeric hot spots.

Each kernel operates on a *padded dense block* representation of one
sub-graph's adjacency (GoFS sub-graphs are small relative to the whole
graph; Gopher pads each sub-graph to the next rung of a block-size ladder
and dispatches to the matching AOT-compiled executable).

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and correctness on this testbed is
what we validate. TPU tiling choices (BlockSpec ladder) are still made as
if for VMEM — see DESIGN.md §Hardware-Adaptation.
"""

from .pagerank import pagerank_step_pallas
from .minplus import minplus_relax_pallas
from .maxprop import maxprop_step_pallas

__all__ = [
    "pagerank_step_pallas",
    "minplus_relax_pallas",
    "maxprop_step_pallas",
]
