"""AOT: lower the Layer-2 model functions to HLO *text* artifacts.

The interchange format is HLO text, **not** ``serialize()``-d
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids which
the Rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md).

Each model function is lowered once per rung of the block-size ladder;
``rust/src/runtime/engine.rs`` pads every sub-graph to the next rung and
dispatches to the matching executable. A plain-text manifest
(``artifacts/manifest.txt``) records kernel name, file, rung and the
compile-time loop count, one per line::

    pagerank_step pagerank_step_128.hlo.txt 128 1
    sssp_relax sssp_relax_128.hlo.txt 128 8

Run via ``make artifacts`` (no-op when inputs are unchanged). Python never
runs after this point: the Rust binary is self-contained.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Block-size ladder: padded sub-graph sizes we compile executables for.
# 64..512 covers the sub-graph size distribution of the evaluation graphs;
# larger sub-graphs fall back to the Rust scalar path (or tile over rungs).
LADDER = (64, 128, 256, 512)
# Compile-time inner-loop counts (see model.py docstrings).
PAGERANK_LOCAL_ITERS = 10
SSSP_SWEEPS = 8
CC_SWEEPS = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_entries():
    """Yield (kernel_name, rung, loop_count, lowered) for every artifact."""
    for n in LADDER:
        adj = _spec(n, n)
        vec = _spec(n)
        two = _spec(2)

        yield (
            "pagerank_step", n, 1,
            jax.jit(model.pagerank_step).lower(adj, vec, vec, two),
        )
        yield (
            "pagerank_local", n, PAGERANK_LOCAL_ITERS,
            jax.jit(
                functools.partial(model.pagerank_local,
                                  iters=PAGERANK_LOCAL_ITERS)
            ).lower(adj, vec, two),
        )
        yield (
            "sssp_relax", n, SSSP_SWEEPS,
            jax.jit(
                functools.partial(model.sssp_relax, sweeps=SSSP_SWEEPS)
            ).lower(adj, vec),
        )
        yield (
            "cc_flood", n, CC_SWEEPS,
            jax.jit(
                functools.partial(model.cc_flood, sweeps=CC_SWEEPS)
            ).lower(adj, vec),
        )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest_lines = []
    for name, n, loops, lowered in build_entries():
        fname = f"{name}_{n}.hlo.txt"
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {fname} {n} {loops}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(manifest_lines)} entries")


if __name__ == "__main__":
    main()
