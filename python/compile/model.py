"""Layer-2 JAX compute graphs for GoFFish per-sub-graph analytics.

These are the functions Gopher's hot path actually executes (after AOT
lowering to HLO, loaded by ``rust/src/runtime``). Each one composes the
Layer-1 Pallas kernels with the graph-semantics bookkeeping that the paper
keeps *inside* a sub-graph's shared-memory computation:

* ``pagerank_step``  — one damped PageRank iteration over a padded dense
  sub-graph block (classic PageRank: Gopher calls it once per superstep).
* ``pagerank_local`` — ``ITERS`` iterations via ``lax.scan`` (BlockRank's
  local phase: rank a sub-graph in isolation in one superstep).
* ``sssp_relax``     — ``k`` min-plus sweeps via ``lax.scan`` (sub-graph
  internal shortest-path closure between message exchanges).
* ``cc_flood``       — ``k`` max-label floods via ``lax.scan``.

Padded-block convention (shared with rust/src/runtime/engine.rs):
sub-graphs are densified into the next block-ladder rung ``n``; rows past
the live vertex count are *padding* and are marked by ``out_deg = -1``
(PageRank), by ``+inf`` weight rows/cols (SSSP), or by zero adjacency rows
(CC). All model functions keep padding inert so the Rust side can slice
the first ``n_live`` outputs and ignore the rest.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    pagerank_step_pallas,
    minplus_relax_pallas,
    maxprop_step_pallas,
)


def pagerank_step(adj, ranks, out_deg, scalars):
    """One damped PageRank iteration over a padded dense block.

    Args:
      adj: ``(n, n)`` f32 in-adjacency (``adj[i, j] = 1`` iff edge j->i).
      ranks: ``(n,)`` f32 current ranks (padding rows 0).
      out_deg: ``(n,)`` f32 *global* out-degrees; ``-1`` marks padding,
        ``0`` marks dangling vertices (handled by the base term upstream).
      scalars: ``(2,)`` f32 ``[base, alpha]`` where ``base`` already folds
        the teleport term and any dangling-mass share computed by Gopher.

    Returns:
      ``(n,)`` f32 updated ranks, padding frozen at 0.
    """
    live = out_deg >= 0.0
    safe_deg = jnp.where(out_deg > 0.0, out_deg, 1.0)
    contrib = jnp.where(out_deg > 0.0, ranks / safe_deg, 0.0)
    new_ranks = pagerank_step_pallas(adj, contrib, scalars)
    return jnp.where(live, new_ranks, 0.0)


def pagerank_local(adj, out_deg, scalars, *, iters):
    """BlockRank local phase: run ``iters`` PageRank iterations in-block.

    Ranks start uniform at ``1/n_total`` over live vertices, where
    ``n_total`` is recovered from ``scalars``: the caller passes
    ``base = (1 - alpha) / n_total`` — exactly the classic teleport term —
    so ``n_total = (1 - alpha) / base``.

    Returns the converged (after ``iters`` steps) in-block ranks.
    """
    base, alpha = scalars[0], scalars[1]
    n_total = (1.0 - alpha) / base
    live = out_deg >= 0.0
    ranks0 = jnp.where(live, 1.0 / n_total, 0.0)

    def body(ranks, _):
        return pagerank_step(adj, ranks, out_deg, scalars), None

    ranks, _ = jax.lax.scan(body, ranks0, None, length=iters)
    return ranks


def sssp_relax(weights, dist, *, sweeps):
    """``sweeps`` min-plus relaxation sweeps over a padded weight block.

    Args:
      weights: ``(n, n)`` f32, ``weights[i, j]`` = weight of edge j->i,
        ``+inf`` for non-edges and anything touching padding.
      dist: ``(n,)`` f32 tentative distances (``+inf`` = unreached).

    Returns:
      ``(n,)`` f32 improved distances. With ``sweeps >= n_live - 1`` this
      is the full shortest-path closure within the block.
    """

    def body(d, _):
        return minplus_relax_pallas(weights, d), None

    dist, _ = jax.lax.scan(body, dist, None, length=sweeps)
    return dist


def cc_flood(adj, labels, *, sweeps):
    """``sweeps`` max-label flood steps over a padded adjacency block.

    Padding rows have all-zero adjacency, so their labels never change and
    never propagate (the Rust side seeds padding labels with ``-inf``).
    """

    def body(lab, _):
        return maxprop_step_pallas(adj, lab), None

    labels, _ = jax.lax.scan(body, labels, None, length=sweeps)
    return labels
