"""Kernel-vs-reference correctness: the CORE numeric signal.

Every Layer-1 Pallas kernel is swept against its pure-jnp oracle in
``kernels/ref.py`` under hypothesis-driven shape / density / seed / dtype
variation. These run in interpret mode (the same lowering the AOT
artifacts use), so passing here certifies the numerics the Rust runtime
will execute.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import (
    pagerank_step_pallas,
    minplus_relax_pallas,
    maxprop_step_pallas,
)
from compile.kernels import ref

# Block sizes exercised by tests: small (fast under interpret tracing) but
# covering 1-block and multi-block grids, including the AOT ladder base.
SIZES = st.sampled_from([4, 8, 16, 32, 64])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)
DENSITIES = st.sampled_from([0.0, 0.05, 0.3, 1.0])


def _rand_adj(rng, n, density, dtype=np.float32):
    a = (rng.random((n, n)) < density).astype(dtype)
    np.fill_diagonal(a, 0)
    return a


def _block_rows(n):
    """Exercise multi-block grids whenever the size allows."""
    return max(4, n // 4) if n >= 8 else n


# ---------------------------------------------------------------- pagerank

@settings(max_examples=25, deadline=None)
@given(n=SIZES, seed=SEEDS, density=DENSITIES)
def test_pagerank_step_matches_ref(n, seed, density):
    rng = np.random.default_rng(seed)
    adj = _rand_adj(rng, n, density)
    contrib = rng.random(n).astype(np.float32)
    scalars = np.array([0.15 / n, 0.85], dtype=np.float32)
    got = pagerank_step_pallas(jnp.asarray(adj), jnp.asarray(contrib),
                               jnp.asarray(scalars),
                               block_rows=_block_rows(n))
    want = ref.pagerank_step_ref(adj, contrib, scalars)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_pagerank_step_empty_graph():
    """No edges: every rank collapses to the base term."""
    n = 16
    adj = np.zeros((n, n), dtype=np.float32)
    contrib = np.ones(n, dtype=np.float32)
    scalars = np.array([0.25, 0.85], dtype=np.float32)
    got = pagerank_step_pallas(jnp.asarray(adj), jnp.asarray(contrib),
                               jnp.asarray(scalars))
    np.testing.assert_allclose(np.asarray(got), np.full(n, 0.25), rtol=1e-6)


def test_pagerank_step_single_block_vs_multi_block():
    """Grid decomposition must not change the numbers."""
    n, seed = 32, 7
    rng = np.random.default_rng(seed)
    adj = _rand_adj(rng, n, 0.2)
    contrib = rng.random(n).astype(np.float32)
    scalars = np.array([0.01, 0.85], dtype=np.float32)
    one = pagerank_step_pallas(jnp.asarray(adj), jnp.asarray(contrib),
                               jnp.asarray(scalars), block_rows=n)
    many = pagerank_step_pallas(jnp.asarray(adj), jnp.asarray(contrib),
                                jnp.asarray(scalars), block_rows=8)
    np.testing.assert_allclose(np.asarray(one), np.asarray(many), rtol=1e-6)


# ----------------------------------------------------------------- minplus

@settings(max_examples=25, deadline=None)
@given(n=SIZES, seed=SEEDS, density=DENSITIES)
def test_minplus_relax_matches_ref(n, seed, density):
    rng = np.random.default_rng(seed)
    mask = _rand_adj(rng, n, density) > 0
    w = np.where(mask, rng.random((n, n)).astype(np.float32) * 10 + 0.1,
                 np.float32(np.inf))
    dist = np.where(rng.random(n) < 0.3,
                    rng.random(n).astype(np.float32) * 5,
                    np.float32(np.inf)).astype(np.float32)
    got = minplus_relax_pallas(jnp.asarray(w), jnp.asarray(dist),
                               block_rows=_block_rows(n))
    want = ref.minplus_relax_ref(w, dist)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)


def test_minplus_all_unreachable_stays_inf():
    n = 8
    w = np.full((n, n), np.inf, dtype=np.float32)
    dist = np.full(n, np.inf, dtype=np.float32)
    got = minplus_relax_pallas(jnp.asarray(w), jnp.asarray(dist))
    assert np.all(np.isinf(np.asarray(got)))


def test_minplus_source_improves_neighbors():
    """A single 0-distance source relaxes exactly its out-neighbours."""
    n = 8
    w = np.full((n, n), np.inf, dtype=np.float32)
    w[3, 0] = 2.5  # edge 0 -> 3 (in-link orientation)
    dist = np.full(n, np.inf, dtype=np.float32)
    dist[0] = 0.0
    got = np.asarray(minplus_relax_pallas(jnp.asarray(w), jnp.asarray(dist)))
    assert got[0] == 0.0
    assert got[3] == pytest.approx(2.5)
    assert np.all(np.isinf(np.delete(got, [0, 3])))


# ----------------------------------------------------------------- maxprop

@settings(max_examples=25, deadline=None)
@given(n=SIZES, seed=SEEDS, density=DENSITIES)
def test_maxprop_step_matches_ref(n, seed, density):
    rng = np.random.default_rng(seed)
    adj = _rand_adj(rng, n, density)
    adj = np.maximum(adj, adj.T)  # undirected components
    labels = rng.permutation(n).astype(np.float32)
    got = maxprop_step_pallas(jnp.asarray(adj), jnp.asarray(labels),
                              block_rows=_block_rows(n))
    want = ref.maxprop_step_ref(adj, labels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


def test_maxprop_isolated_vertices_keep_labels():
    n = 16
    adj = np.zeros((n, n), dtype=np.float32)
    labels = np.arange(n, dtype=np.float32)
    got = maxprop_step_pallas(jnp.asarray(adj), jnp.asarray(labels))
    np.testing.assert_array_equal(np.asarray(got), labels)


def test_maxprop_converges_to_component_max():
    """Iterating the kernel labels each component with its max vertex id."""
    n = 8
    edges = [(0, 1), (1, 2), (4, 5)]  # components {0,1,2},{4,5},{3},{6},{7}
    adj = np.zeros((n, n), dtype=np.float32)
    for u, v in edges:
        adj[u, v] = adj[v, u] = 1.0
    labels = jnp.asarray(np.arange(n, dtype=np.float32))
    for _ in range(n):
        labels = maxprop_step_pallas(jnp.asarray(adj), labels)
    got = np.asarray(labels)
    np.testing.assert_array_equal(got, [2, 2, 2, 3, 5, 5, 6, 7])


# ------------------------------------------------------------------- dtype

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_minplus_dtype_sweep(dtype):
    """minplus is min/add only — exact in any float dtype vs same-dtype ref."""
    n = 16
    rng = np.random.default_rng(0)
    mask = _rand_adj(rng, n, 0.3) > 0
    w = jnp.where(jnp.asarray(mask),
                  jnp.asarray(rng.integers(1, 16, (n, n))).astype(dtype),
                  jnp.asarray(float("inf"), dtype=dtype))
    dist = jnp.where(jnp.arange(n) == 0, 0, float("inf")).astype(dtype)
    got = minplus_relax_pallas(w, dist)
    want = ref.minplus_relax_ref(w, dist)
    assert got.dtype == dist.dtype
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_maxprop_dtype_sweep(dtype):
    n = 16
    rng = np.random.default_rng(1)
    adj0 = _rand_adj(rng, n, 0.3)
    adj0 = np.maximum(adj0, adj0.T)
    adj = jnp.asarray(adj0).astype(dtype)
    labels = jnp.asarray(np.arange(n)).astype(dtype)
    got = maxprop_step_pallas(adj, labels)
    want = ref.maxprop_step_ref(adj, labels)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))
