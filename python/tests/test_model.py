"""Layer-2 model semantics: graph-level invariants of the compute graphs.

The kernels are certified against refs in test_kernels.py; here we test
what the *model* promises Gopher: padding stays inert, PageRank mass is
conserved on closed blocks, SSSP closure equals Dijkstra, CC flood labels
components with their max id.
"""

import heapq

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _rand_digraph(rng, n, density):
    a = (rng.random((n, n)) < density).astype(np.float32)
    np.fill_diagonal(a, 0)
    return a  # a[i, j] = 1 iff edge j -> i


def _dijkstra(w_in, source):
    """Plain heap Dijkstra on the in-link weight matrix (oracle)."""
    n = w_in.shape[0]
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    pq = [(0.0, source)]
    done = set()
    while pq:
        d, u = heapq.heappop(pq)
        if u in done:
            continue
        done.add(u)
        for v in range(n):
            wv = w_in[v, u]  # edge u -> v
            if np.isfinite(wv) and d + wv < dist[v]:
                dist[v] = d + wv
                heapq.heappush(pq, (dist[v], v))
    return dist


# ---------------------------------------------------------- pagerank_step

@settings(max_examples=15, deadline=None)
@given(seed=SEEDS)
def test_pagerank_step_padding_inert(seed):
    n, live = 16, 11
    rng = np.random.default_rng(seed)
    adj = _rand_digraph(rng, n, 0.3)
    adj[live:, :] = 0
    adj[:, live:] = 0
    ranks = np.zeros(n, dtype=np.float32)
    ranks[:live] = 1.0 / live
    out_deg = np.concatenate([
        adj[:, :live].sum(axis=0).astype(np.float32),
        np.full(n - live, -1.0, dtype=np.float32),
    ])[:n]
    out_deg = np.where(np.arange(n) < live,
                       adj.sum(axis=0), -1.0).astype(np.float32)
    scalars = np.array([0.15 / live, 0.85], dtype=np.float32)
    got = np.asarray(model.pagerank_step(
        jnp.asarray(adj), jnp.asarray(ranks), jnp.asarray(out_deg),
        jnp.asarray(scalars)))
    assert np.all(got[live:] == 0.0), "padding rows must stay at rank 0"
    assert np.all(got[:live] >= scalars[0] - 1e-7)


def test_pagerank_mass_conserved_on_closed_block():
    """On a strongly-connected dangling-free block, total rank mass -> 1."""
    n = 16
    # Directed ring + extra chords: every vertex has outdeg >= 1.
    adj = np.zeros((n, n), dtype=np.float32)
    for j in range(n):
        adj[(j + 1) % n, j] = 1.0
        adj[(j + 5) % n, j] = 1.0
    out_deg = adj.sum(axis=0).astype(np.float32)
    scalars = np.array([0.15 / n, 0.85], dtype=np.float32)
    ranks = jnp.asarray(np.full(n, 1.0 / n, dtype=np.float32))
    for _ in range(50):
        ranks = model.pagerank_step(jnp.asarray(adj), ranks,
                                    jnp.asarray(out_deg),
                                    jnp.asarray(scalars))
    assert float(jnp.sum(ranks)) == pytest.approx(1.0, rel=1e-4)


# --------------------------------------------------------- pagerank_local

@settings(max_examples=10, deadline=None)
@given(seed=SEEDS)
def test_pagerank_local_matches_unrolled_ref(seed):
    n, iters = 16, 10
    rng = np.random.default_rng(seed)
    adj = _rand_digraph(rng, n, 0.25)
    out_deg = adj.sum(axis=0).astype(np.float32)
    n_total = 64.0  # pretend the block is part of a larger graph
    alpha = 0.85
    scalars = np.array([(1 - alpha) / n_total, alpha], dtype=np.float32)
    got = np.asarray(model.pagerank_local(
        jnp.asarray(adj), jnp.asarray(out_deg), jnp.asarray(scalars),
        iters=iters))
    want = np.asarray(ref.pagerank_full_ref(
        jnp.asarray(adj), jnp.asarray(out_deg), n_total, alpha, iters))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


# ------------------------------------------------------------- sssp_relax

@settings(max_examples=15, deadline=None)
@given(seed=SEEDS)
def test_sssp_closure_equals_dijkstra(seed):
    n = 16
    rng = np.random.default_rng(seed)
    mask = _rand_digraph(rng, n, 0.25) > 0
    w = np.where(mask, (rng.random((n, n)) * 9 + 1).astype(np.float32),
                 np.float32(np.inf))
    dist0 = np.where(np.arange(n) == 0, 0.0, np.inf).astype(np.float32)
    # n sweeps guarantee closure on a 16-vertex block (model compiles 8 per
    # call; Gopher loops calls to fixpoint — emulate two calls here).
    d = jnp.asarray(dist0)
    for _ in range(2):
        d = model.sssp_relax(jnp.asarray(w), d, sweeps=8)
    want = _dijkstra(w, 0)
    np.testing.assert_allclose(np.asarray(d), want.astype(np.float32),
                               rtol=1e-5)


def test_sssp_padding_stays_unreachable():
    n, live = 8, 5
    w = np.full((n, n), np.inf, dtype=np.float32)
    for u, v in [(0, 1), (1, 2), (2, 3), (3, 4)]:
        w[v, u] = 1.0
    dist0 = np.where(np.arange(n) == 0, 0.0, np.inf).astype(np.float32)
    d = model.sssp_relax(jnp.asarray(w), jnp.asarray(dist0), sweeps=8)
    got = np.asarray(d)
    np.testing.assert_allclose(got[:live], [0, 1, 2, 3, 4])
    assert np.all(np.isinf(got[live:]))


# --------------------------------------------------------------- cc_flood

@settings(max_examples=15, deadline=None)
@given(seed=SEEDS)
def test_cc_flood_labels_equal_components(seed):
    n = 16
    rng = np.random.default_rng(seed)
    adj = _rand_digraph(rng, n, 0.12)
    adj = np.maximum(adj, adj.T)
    labels = jnp.asarray(np.arange(n, dtype=np.float32))
    for _ in range(4):  # 4 calls x 8 sweeps >= diameter of any 16-block
        labels = model.cc_flood(jnp.asarray(adj), labels, sweeps=8)
    got = np.asarray(labels).astype(int)

    # Union-find ground truth.
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for i in range(n):
        for j in range(n):
            if adj[i, j] > 0:
                parent[find(i)] = find(j)
    comp_max = {}
    for v in range(n):
        r = find(v)
        comp_max[r] = max(comp_max.get(r, -1), v)
    want = np.array([comp_max[find(v)] for v in range(n)])
    np.testing.assert_array_equal(got, want)
