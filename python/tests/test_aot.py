"""AOT lowering sanity: artifacts are HLO text the Rust loader accepts.

Full artifact generation is exercised by ``make artifacts``; here we lower
a representative rung per entry point, check the HLO text is well-formed
(module header + f32 entry layout) and that no Mosaic custom-call leaked
in (which would be unrunnable on the CPU PJRT plugin).
"""

import functools

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def _lower_one(name):
    n = 64
    adj = jax.ShapeDtypeStruct((n, n), jnp.float32)
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    two = jax.ShapeDtypeStruct((2,), jnp.float32)
    if name == "pagerank_step":
        return jax.jit(model.pagerank_step).lower(adj, vec, vec, two)
    if name == "pagerank_local":
        return jax.jit(functools.partial(model.pagerank_local,
                                         iters=2)).lower(adj, vec, two)
    if name == "sssp_relax":
        return jax.jit(functools.partial(model.sssp_relax,
                                         sweeps=2)).lower(adj, vec)
    if name == "cc_flood":
        return jax.jit(functools.partial(model.cc_flood,
                                         sweeps=2)).lower(adj, vec)
    raise AssertionError(name)


@pytest.mark.parametrize(
    "name", ["pagerank_step", "pagerank_local", "sssp_relax", "cc_flood"])
def test_hlo_text_well_formed(name):
    text = aot.to_hlo_text(_lower_one(name))
    assert text.startswith("HloModule"), text[:80]
    assert "entry_computation_layout" in text.splitlines()[0]
    assert "f32[64,64]" in text
    # interpret=True must have erased all Mosaic/TPU custom-calls.
    assert "custom-call" not in text, "unrunnable custom-call leaked into HLO"


def test_manifest_entries_cover_ladder():
    entries = list(aot.build_entries())
    names = {(name, n) for name, n, _, _ in entries}
    for n in aot.LADDER:
        for kernel in ("pagerank_step", "pagerank_local",
                       "sssp_relax", "cc_flood"):
            assert (kernel, n) in names


def test_hlo_output_is_tuple_wrapped():
    """Rust side unwraps with to_tuple1 — lowering must return a 1-tuple."""
    text = aot.to_hlo_text(_lower_one("pagerank_step"))
    first = text.splitlines()[0]
    # entry layout like ...->(f32[64]{0})} : tuple of one result
    assert "->(f32[64]" in first, first
