//! SSSP on a road network: the paper's flagship traversal win (§6.4).
//!
//! Runs sub-graph centric SSSP (Dijkstra inside each sub-graph per
//! superstep, Algorithm 3) against the vertex-centric baseline on the
//! same weighted road-network analog, verifying both agree and showing
//! the superstep collapse that drives the paper's 78x.
//!
//! ```bash
//! cargo run --release --example sssp_roadnet [-- scale]
//! ```

use std::collections::BTreeMap;

use goffish::algos::sssp::{SsspSg, SsspVx};
use goffish::algos::gather_vertex_values;
use goffish::gofs::subgraph::discover;
use goffish::gopher::{run, GopherConfig};
use goffish::graph::gen;
use goffish::partition::{HashPartitioner, MultilevelPartitioner, Partitioner};
use goffish::pregel::{run_vertex, PregelConfig};

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.15);
    let k = 4;
    let g = gen::with_random_weights(&gen::rn_analog(scale, 7), 1.0, 10.0, 8);
    println!(
        "road analog: {} vertices, {} edges (scale {scale})",
        g.num_vertices(),
        g.num_edges()
    );
    let source = 0u32;

    // Gopher (sub-graph centric).
    let parts = MultilevelPartitioner::default().partition(&g, k);
    let dg = discover(&g, &parts)?;
    let sg_res = run(&dg, &SsspSg { source }, &GopherConfig::default())?;
    let states: BTreeMap<_, Vec<f32>> = sg_res
        .states
        .into_iter()
        .map(|(id, s)| (id, s.dist))
        .collect();
    let sg_dist = gather_vertex_values(&dg, &states);
    println!("{}", sg_res.metrics.report("gopher/sssp"));

    // Vertex-centric baseline (Giraph stand-in).
    let vparts = HashPartitioner::default().partition(&g, k);
    let vx_res = run_vertex(&g, &vparts, &SsspVx { source }, &PregelConfig::default())?;
    println!("{}", vx_res.metrics.report("vertex/sssp"));

    // Agreement.
    let mut max_diff = 0f32;
    for (&a, &b) in sg_dist.iter().zip(&vx_res.values) {
        if a.is_finite() && b.is_finite() {
            max_diff = max_diff.max((a - b).abs());
        } else {
            assert_eq!(a.is_finite(), b.is_finite());
        }
    }
    println!("max distance diff: {max_diff:e}");

    let ratio = vx_res.metrics.num_supersteps() as f64 / sg_res.metrics.num_supersteps() as f64;
    println!(
        "supersteps: gopher {} vs vertex {} — {:.1}x fewer (paper: 84 vs 1000+ on RN)",
        sg_res.metrics.num_supersteps(),
        vx_res.metrics.num_supersteps(),
        ratio
    );
    Ok(())
}
