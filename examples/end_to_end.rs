//! End-to-end driver: the full GoFFish system on real (synthetic-analog)
//! workloads, reproducing the paper's headline comparison.
//!
//! For each dataset analog (RN / TR / LJ, Table 1) and each algorithm
//! (CC / SSSP / PageRank, §6): generate → partition (METIS-like) → GoFS
//! store on disk → run with Gopher *from disk* → run the vertex-centric
//! Giraph stand-in on the same graph → assert result parity → print the
//! paper-style makespan / superstep / message table with speedups.
//!
//! Recorded in EXPERIMENTS.md. Scale with an argument:
//!
//! ```bash
//! cargo run --release --example end_to_end [-- scale]   # default 0.1
//! ```

use std::collections::BTreeMap;

use goffish::algos::cc::{CcSg, CcVx};
use goffish::algos::pagerank::{PageRankSg, PageRankVx, RankKernel};
use goffish::algos::sssp::{SsspSg, SsspVx};
use goffish::algos::{gather_subgraph_values, gather_vertex_values};
use goffish::bench::{fmt_secs, fmt_speedup, Table};
use goffish::gofs::Store;
use goffish::gopher::{run_on_store, GopherConfig};
use goffish::graph::{gen, props, Graph};
use goffish::metrics::JobMetrics;
use goffish::partition::{HashPartitioner, MultilevelPartitioner, Partitioner};
use goffish::pregel::{run_vertex, PregelConfig};

const K: usize = 4; // simulated hosts (paper: 12; laptop default: 4)

fn main() -> anyhow::Result<()> {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.1);
    let datasets: Vec<(&str, Graph)> = vec![
        ("RN", gen::rn_analog(scale, 11)),
        ("TR", gen::tr_analog(scale, 22)),
        ("LJ", gen::lj_analog(scale, 33)),
    ];

    let mut table = Table::new(
        &format!("End-to-end: GoFFish vs vertex baseline (scale {scale}, k={K})"),
        &["dataset", "algo", "gopher", "vertex", "speedup", "ss(g)", "ss(v)", "msgs(g)", "msgs(v)", "parity"],
    );

    for (name, g) in &datasets {
        println!(
            "\n--- {name}: {} vertices, {} edges, wcc {}, diameter~{}",
            g.num_vertices(),
            g.num_edges(),
            props::wcc_count(g),
            props::diameter_estimate(g, 3, 5)
        );
        let parts = MultilevelPartitioner::default().partition(g, K);
        let root = std::env::temp_dir().join(format!(
            "goffish_e2e_{}_{}",
            name,
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&root);
        let (store, dg) = Store::create(&root, name, g, &parts)?;
        let vparts = HashPartitioner::default().partition(g, K);
        let gcfg = GopherConfig::default();
        let vcfg = PregelConfig::default();

        // SSSP source: the max-out-degree vertex (vertex 0 of the directed
        // analogs can have zero out-edges, which reaches nothing).
        let source = (0..g.num_vertices() as u32)
            .max_by_key(|&v| g.out_degree(v))
            .unwrap_or(0);

        for algo in ["cc", "sssp", "pagerank"] {
            let (gm, vm, parity): (JobMetrics, JobMetrics, bool) = match algo {
                "cc" => {
                    let gres = run_on_store(&store, &CcSg, &gcfg)?;
                    let vres = run_vertex(g, &vparts, &CcVx, &vcfg)?;
                    let glabels = gather_subgraph_values(&dg, &gres.states);
                    (gres.metrics, vres.metrics, glabels == vres.values)
                }
                "sssp" => {
                    let gres = run_on_store(&store, &SsspSg { source }, &gcfg)?;
                    let vres = run_vertex(g, &vparts, &SsspVx { source }, &vcfg)?;
                    let states: BTreeMap<_, Vec<f32>> = gres
                        .states
                        .into_iter()
                        .map(|(id, s)| (id, s.dist))
                        .collect();
                    let gdist = gather_vertex_values(&dg, &states);
                    let parity = gdist.iter().zip(&vres.values).all(|(&a, &b)| {
                        (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-3
                    });
                    (gres.metrics, vres.metrics, parity)
                }
                _ => {
                    let prog =
                        PageRankSg { supersteps: 30, kernel: RankKernel::Scalar, epsilon: None };
                    let gres = run_on_store(&store, &prog, &gcfg)?;
                    let vres =
                        run_vertex(g, &vparts, &PageRankVx { supersteps: 30 }, &vcfg)?;
                    let states: BTreeMap<_, Vec<f32>> = gres
                        .states
                        .into_iter()
                        .map(|(id, s)| (id, s.ranks))
                        .collect();
                    let granks = gather_vertex_values(&dg, &states);
                    let parity = granks
                        .iter()
                        .zip(&vres.values)
                        .all(|(&a, &b)| (a - b).abs() < 1e-5 + 1e-3 * b.abs());
                    (gres.metrics, vres.metrics, parity)
                }
            };
            assert!(parity, "{name}/{algo}: engines disagree");
            table.row(&[
                name.to_string(),
                algo.to_string(),
                fmt_secs(gm.makespan_seconds()),
                fmt_secs(vm.makespan_seconds()),
                fmt_speedup(vm.makespan_seconds() / gm.makespan_seconds()),
                gm.num_supersteps().to_string(),
                vm.num_supersteps().to_string(),
                gm.total_messages().to_string(),
                vm.total_messages().to_string(),
                "ok".to_string(),
            ]);
        }
    }
    table.print();
    println!("\nAll engine pairs agreed on results. OK");
    Ok(())
}
