//! PageRank + BlockRank with the AOT XLA kernels: the paper §7's "fast
//! shared-memory kernels within a sub-graph" as a working feature.
//!
//! Loads the Pallas/JAX-compiled HLO artifacts via PJRT, runs Gopher
//! PageRank with the `pagerank_step` block kernel on every sub-graph that
//! fits the ladder, verifies against the scalar path, then runs BlockRank
//! (local phase = the `pagerank_local` scan kernel) and reports the
//! superstep saving.
//!
//! ```bash
//! make artifacts && cargo run --release --example pagerank_xla
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use goffish::algos::blockrank::BlockRankSg;
use goffish::algos::gather_vertex_values;
use goffish::algos::pagerank::{PageRankSg, RankKernel};
use goffish::gofs::subgraph::discover;
use goffish::gopher::{run, GopherConfig};
use goffish::graph::gen;
use goffish::partition::{MultilevelPartitioner, Partitioner};
use goffish::runtime::XlaEngine;

fn main() -> anyhow::Result<()> {
    let engine = Arc::new(XlaEngine::load_default()?);
    println!(
        "xla engine: rung ladder up to {} (pagerank_local iters={})",
        engine.max_rung(),
        engine.loops("pagerank_local")
    );

    let g = gen::lj_analog(0.05, 3);
    println!("social analog: {} vertices, {} edges", g.num_vertices(), g.num_edges());
    let parts = MultilevelPartitioner::default().partition(&g, 4);
    let dg = discover(&g, &parts)?;
    let small = dg
        .subgraphs()
        .filter(|s| s.num_vertices() <= engine.max_rung())
        .count();
    println!(
        "{} of {} sub-graphs fit the XLA block ladder",
        small,
        dg.num_subgraphs()
    );

    // PageRank: scalar vs XLA kernels must agree.
    let ranks = |kernel: RankKernel| -> anyhow::Result<(Vec<f32>, f64)> {
        let prog = PageRankSg { supersteps: 30, kernel, epsilon: None };
        let res = run(&dg, &prog, &GopherConfig::default())?;
        let wall = res.metrics.compute_seconds;
        let states: BTreeMap<_, Vec<f32>> =
            res.states.into_iter().map(|(id, s)| (id, s.ranks)).collect();
        Ok((gather_vertex_values(&dg, &states), wall))
    };
    let (scalar, t_scalar) = ranks(RankKernel::Scalar)?;
    let (xla, t_xla) = ranks(RankKernel::Xla(engine.clone()))?;
    let max_diff = scalar
        .iter()
        .zip(&xla)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("pagerank scalar {t_scalar:.3}s vs xla {t_xla:.3}s, max rank diff {max_diff:e}");
    assert!(max_diff < 1e-6, "XLA and scalar paths diverged");

    // Top-5 ranked vertices.
    let mut idx: Vec<usize> = (0..xla.len()).collect();
    idx.sort_by(|&a, &b| xla[b].partial_cmp(&xla[a]).unwrap());
    println!("top ranks: {:?}", &idx[..5.min(idx.len())]);

    // BlockRank with the XLA local phase: fewer supersteps to converge.
    let directory: Vec<u32> = dg.partitions.iter().map(|p| p.len() as u32).collect();
    let mut br = BlockRankSg::new(&directory);
    br.kernel = RankKernel::Xla(engine);
    let cfg = GopherConfig { max_supersteps: 500, ..Default::default() };
    let br_res = run(&dg, &br, &cfg)?;
    println!(
        "blockrank converged in {} supersteps (classic PageRank: fixed 30)",
        br_res.metrics.num_supersteps()
    );
    println!("OK");
    Ok(())
}
