//! Quickstart: the GoFFish API in ~40 lines.
//!
//! Generate a small road network, partition it, build a GoFS store, and
//! run Connected Components through the unified job layer — once per
//! engine — printing the component count plus job metrics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use goffish::algos::cc::count_components;
use goffish::gofs::Store;
use goffish::graph::{gen, props};
use goffish::job::{EngineKind, Job, JobSource};
use goffish::partition::{MultilevelPartitioner, Partitioner};

fn main() -> anyhow::Result<()> {
    // 1. A graph: 60x60 road-like lattice with dropped edges.
    let g = gen::road(60, 0.95, 0.005, 42);
    println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());

    // 2. Partition across 4 simulated hosts (METIS-like multilevel).
    let parts = MultilevelPartitioner::default().partition(&g, 4);
    println!("partition: cut {:?}", parts.metrics(&g).edge_cut);

    // 3. Build the GoFS store (sub-graph discovery + slice files).
    let root = std::env::temp_dir().join(format!("goffish_quickstart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let (store, dg) = Store::create(&root, "quickstart", &g, &parts)?;
    println!(
        "gofs: {} sub-graphs across {} hosts",
        dg.num_subgraphs(),
        store.meta().num_partitions
    );

    // 4. One job description, any engine, any source: Connected
    //    Components with Gopher against the on-disk store…
    let job = Job::builder().algo("cc").engine(EngineKind::Gopher).build()?;
    let out = job.run(JobSource::Store(&store))?;

    // 5. …with uniform per-vertex output.
    let labels: Vec<u32> = out.values.iter().map(|&(_, l)| l as u32).collect();
    println!(
        "components: {} (ground truth {})",
        count_components(&labels),
        props::wcc_count(&g)
    );
    println!("{}", out.metrics.report("quickstart/cc/gopher"));
    assert_eq!(count_components(&labels), props::wcc_count(&g));

    // 6. The vertex-centric baseline is one builder knob away and must
    //    agree per vertex.
    let vout = Job::builder()
        .algo("cc")
        .engine(EngineKind::Vertex)
        .build()?
        .run(JobSource::Store(&store))?;
    println!("{}", vout.metrics.report("quickstart/cc/vertex"));
    assert_eq!(out.values, vout.values);
    println!("OK");
    Ok(())
}
