//! Quickstart: the GoFFish API in ~40 lines.
//!
//! Generate a small road network, partition it, build a GoFS store, run
//! sub-graph centric Connected Components with Gopher, and print the
//! component count plus job metrics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use goffish::algos::cc::{count_components, CcSg};
use goffish::algos::gather_subgraph_values;
use goffish::gofs::Store;
use goffish::gopher::{run_on_store, GopherConfig};
use goffish::graph::{gen, props};
use goffish::partition::{MultilevelPartitioner, Partitioner};

fn main() -> anyhow::Result<()> {
    // 1. A graph: 60x60 road-like lattice with dropped edges.
    let g = gen::road(60, 0.95, 0.005, 42);
    println!("graph: {} vertices, {} edges", g.num_vertices(), g.num_edges());

    // 2. Partition across 4 simulated hosts (METIS-like multilevel).
    let parts = MultilevelPartitioner::default().partition(&g, 4);
    println!("partition: cut {:?}", parts.metrics(&g).edge_cut);

    // 3. Build the GoFS store (sub-graph discovery + slice files).
    let root = std::env::temp_dir().join(format!("goffish_quickstart_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let (store, dg) = Store::create(&root, "quickstart", &g, &parts)?;
    println!(
        "gofs: {} sub-graphs across {} hosts",
        dg.num_subgraphs(),
        store.meta().num_partitions
    );

    // 4. Run sub-graph centric Connected Components with Gopher.
    let res = run_on_store(&store, &CcSg, &GopherConfig::default())?;

    // 5. Inspect results.
    let labels = gather_subgraph_values(&dg, &res.states);
    println!("components: {} (ground truth {})", count_components(&labels), props::wcc_count(&g));
    println!("{}", res.metrics.report("quickstart/cc"));
    assert_eq!(count_components(&labels), props::wcc_count(&g));
    println!("OK");
    Ok(())
}
